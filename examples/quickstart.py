"""Quickstart: fine-tune a small LM with ColA (Gradient Learning) in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import registry
from repro.configs.base import ColaConfig
from repro.core.session import ColaSession
from repro.data.pipeline import SyntheticLM
from repro.models import model as M
from repro.optim import optimizers as opt


def main():
    # a reduced smollm-family model that trains on CPU in seconds
    cfg = registry.reduced_config("smollm-135m").replace(n_layers=2)
    key = jax.random.PRNGKey(0)
    params = M.init(cfg, key)

    # ColA, paper-faithful: merged server pass + offloaded quadratic fit
    cc = ColaConfig(mode="faithful_offload", family="lowrank", rank=8,
                    taps="qv", merged=True, interval=2)
    session = ColaSession(cfg, cc, params, key, optimizer=opt.adamw(3e-3))

    data = SyntheticLM(cfg, batch=8, seq=64, seed=0)
    for step in range(30):
        loss = session.step(data.batch_at(step))
        if step % 5 == 0:
            print(f"step {step:3d}  loss {loss:.4f}")

    print("\nadapters live on the offload device; server held only the "
          "frozen (merged) base model — paper Table 1, ColA (merged) row.")
    merged = session.inference_params()
    logits, _ = M.forward(cfg, merged, data.batch_at(999))
    print("merged-for-inference logits:", logits.shape)


if __name__ == "__main__":
    main()
