"""FTaaS serving: one frozen base model, K users' adapters, continuous
batching with per-request adapter routing (the multi_lora kernel's job).

    PYTHONPATH=src python examples/serve_multi_user.py
"""
import jax
import numpy as np

from repro.configs import registry
from repro.configs.base import ColaConfig
from repro.core import gl
from repro.core.session import ColaSession
from repro.data.pipeline import SyntheticLM
from repro.models import model as M
from repro.optim import optimizers as opt
from repro.runtime.serve_loop import Request, ServeEngine


def main():
    cfg = registry.reduced_config("smollm-135m").replace(n_layers=2)
    key = jax.random.PRNGKey(0)
    params = M.init(cfg, key)

    # fine-tune two users' adapters on different data (FTaaS training half)
    banks = []
    for user in range(2):
        cc = ColaConfig(mode="faithful_offload", family="lowrank", rank=8,
                        taps="qv", merged=True)
        sess = ColaSession(cfg, cc, params, jax.random.fold_in(key, user),
                           optimizer=opt.adamw(3e-3))
        data = SyntheticLM(cfg, batch=8, seq=64, seed=100 + user)
        for t in range(10):
            sess.step(data.batch_at(t))
        banks.append(sess.adapters)
        print(f"user {user}: trained adapter bank")

    # serving half: both users share one engine + one base model. Admission
    # drains waiting requests into free slots and prefills them as one padded
    # batch (submit -> admit -> batched prefill -> decode ticks).
    eng = ServeEngine(cfg, params, slots=4, max_len=128, user_adapters=banks)
    rng = np.random.default_rng(0)
    for rid in range(6):
        eng.submit(Request(rid=rid, user=rid % 2,
                           prompt=rng.integers(0, cfg.vocab_size, size=32),
                           max_new=8))
    eng.run_until_idle()
    print(f"served {eng.stats['completed']} requests, "
          f"{eng.stats['tokens']} tokens in {eng.stats['ticks']} ticks, "
          f"{eng.stats['prefill_tokens']} prompt tokens in "
          f"{eng.stats['prefill_calls']} batched prefills "
          f"(continuous batching, per-token adapter routing)")
    th = eng.throughput()
    print(f"decode {th['decode_tok_per_s']:.0f} tok/s, "
          f"prefill {th['prefill_tok_per_s']:.0f} tok/s, "
          f"mean TTFT {th['mean_ttft']*1e3:.1f} ms")
    for r in eng.request_stats():
        print(f"  rid={r['rid']} user={r['user']} prompt={r['prompt_len']} "
              f"new={r['new_tokens']} ttft={r['ttft']*1e3:.1f}ms "
              f"latency={r['latency']*1e3:.1f}ms")


if __name__ == "__main__":
    main()
