"""End-to-end driver: train a ~100M-param LM with ColA for a few hundred steps
through the full fault-tolerant runtime (checkpointing, watchdog, metrics,
restart-resume).

    PYTHONPATH=src python examples/train_cola_lm.py --steps 300
    # kill it mid-run and re-run: it resumes from the last checkpoint.

Note: ~100M params on this CPU container is slow; --small trains a reduced
model through the identical code path (default). Pass --full for the real
smollm-135m config.
"""
import argparse

import jax

from repro.configs import registry
from repro.configs.base import ColaConfig
from repro.core.session import ColaSession
from repro.data.pipeline import SyntheticLM
from repro.models import model as M
from repro.optim import optimizers as opt
from repro.optim import schedules
from repro.runtime.train_loop import TrainLoop
from repro.utils import human_count, tree_count


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true",
                    help="use the full smollm-135m (~135M params; slow on CPU)")
    ap.add_argument("--workdir", default="/tmp/cola_lm_run")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mode", default="faithful_offload",
                    choices=["faithful_offload", "fused_fit", "lora", "ft"])
    args = ap.parse_args()

    if args.full:
        cfg = registry.get_config("smollm-135m").replace(
            param_dtype="float32", compute_dtype="float32", remat="none")
    else:
        cfg = registry.reduced_config("smollm-135m").replace(
            n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_head=32,
            d_ff=512, vocab_size=4096)

    key = jax.random.PRNGKey(0)
    params = M.init(cfg, key)
    print(f"model: {cfg.name} ({human_count(tree_count(params))} params)")

    lr = schedules.linear_warmup_decay(3e-3, args.steps)
    cc = ColaConfig(mode=args.mode, family="lowrank", rank=8, taps="qv",
                    merged=(args.mode == "faithful_offload"), interval=2)
    session = ColaSession(cfg, cc, params, key, optimizer=opt.adamw(lr))
    data = SyntheticLM(cfg, batch=args.batch, seq=args.seq, seed=0)

    loop = TrainLoop(session, data, args.workdir, ckpt_every=50, log_every=10)
    stats = loop.run(args.steps, resume=True)
    print("run stats:", stats)
    print(f"metrics: {loop.metrics_path}")


if __name__ == "__main__":
    main()
