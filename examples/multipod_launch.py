"""Launch-shaped example: build the production mesh, shard a full assigned
architecture, and run the ColA train step (on the 512 fake host devices —
the same code path a real TPU pod launch uses, minus the hardware).

    PYTHONPATH=src python examples/multipod_launch.py --arch smollm-135m
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=64")

import argparse

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import ColaConfig
from repro.core import gl
from repro.distributed import sharding as sh
from repro.distributed import steps
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--data", type=int, default=4)
    ap.add_argument("--model", type=int, default=4)
    ap.add_argument("--pods", type=int, default=2)
    args = ap.parse_args()

    mesh = jax.make_mesh((args.pods, args.data, args.model),
                         ("pod", "data", "model"))
    print(f"mesh: {dict(mesh.shape)} over {mesh.devices.size} devices")

    cfg = registry.reduced_config(args.arch)
    cc = ColaConfig(mode="fused_fit", family="lowrank", rank=8, taps="qv")
    key = jax.random.PRNGKey(0)
    params = M.init(cfg, key)
    adapters = gl.init_adapters(cfg, cc, key)
    B, S = args.pods * args.data * 2, 64
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}

    with mesh:
        fn, (ps, ash, _), _ = steps.make_train_step(cfg, cc, mesh)
        bs = sh.batch_shardings(mesh, jax.eval_shape(lambda: batch))
        jitted = jax.jit(fn, in_shardings=(ps, ash, bs))
        params = jax.device_put(params, ps)
        adapters = jax.device_put(adapters, ash)
        from repro.optim import optimizers as opt
        optimizer = opt.sgd(0.1)
        opt_state = optimizer.init(adapters)
        for step in range(3):
            loss, grads = jitted(params, adapters, batch)
            updates, opt_state = optimizer.update(grads, opt_state, adapters)
            adapters = opt.apply_updates(adapters, updates)
            print(f"step {step}: loss={float(loss):.4f} "
                  f"(grads sharded: "
                  f"{jax.tree.leaves(grads)[0].sharding.spec})")


if __name__ == "__main__":
    main()
