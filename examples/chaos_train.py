"""Fault-tolerant FTaaS demo: collaborative training over an unreliable
offload transport, with per-user quarantine and validated hot-swaps into the
serving engine.

Two users fine-tune one merged base model (paper §3.2). User 1's channel is
deliberately terrible — payloads get dropped, delayed and NaN-poisoned — while
user 0's is clean. The `OffloadChannel` retries/dedups transit faults,
validates every returned adapter bank, rolls back bad rounds and quarantines
the user if rounds keep failing; `publish_banks` then installs only validated
version bumps into the `ServeEngine` (stale/quarantined users keep serving
their last-good adapters).

    PYTHONPATH=src python examples/chaos_train.py
    PYTHONPATH=src python examples/chaos_train.py --fault nan --rate 1.0
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import ColaConfig
from repro.core.collab import CollabSession
from repro.data.pipeline import SyntheticLM
from repro.models import model as M
from repro.optim import optimizers as opt
from repro.runtime.faults import FaultInjector, FaultProfile, RetryPolicy
from repro.runtime.serve_loop import Request, ServeEngine, publish_banks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fault", default="mixed",
                    choices=["drop", "delay", "corrupt", "duplicate", "nan",
                             "mixed"])
    ap.add_argument("--rate", type=float, default=0.4)
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.fault == "mixed":
        profile = FaultProfile(drop=args.rate / 2, delay=args.rate / 2,
                               delay_ticks=1, nan=args.rate / 2)
    else:
        profile = FaultProfile(**{args.fault: args.rate})
    injector = FaultInjector({1: profile}, seed=args.seed)
    policy = RetryPolicy(max_attempts=6, timeout_ticks=2,
                         backoff_base=1e-4, sleep=lambda s: None)

    cfg = registry.reduced_config("smollm-135m").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=128)
    key = jax.random.PRNGKey(0)
    params = M.init(cfg, key)
    cc = ColaConfig(mode="faithful_offload", family="lowrank", taps="qv",
                    rank=4, merged=True, users=2)
    collab = CollabSession(cfg, cc, params, key, optimizer=opt.sgd(0.1),
                           injector=injector, policy=policy)
    data = SyntheticLM(cfg, batch=4, seq=16, seed=2, users=2)

    print(f"user 1 fault profile: {args.fault} @ {args.rate}  "
          f"(user 0 clean)\n")
    for t in range(args.steps):
        b = data.batch_at(t)
        uid = jnp.asarray(b.pop("user_id"))
        loss = collab.train_step({k: jnp.asarray(v) for k, v in b.items()},
                                 uid)
        print(f"step {t:2d}  loss {loss:.4f}  "
              f"bank versions {collab.bank_versions()}")

    print("\nchannel health:")
    for k, h in collab.channel_health().items():
        flags = " QUARANTINED" if h["quarantined"] else ""
        print(f"  user {k}: v{h['version']}  retries={h['send_retries']} "
              f"rollbacks={h['rollbacks']} dead_letters={h['dead_letter_count']}"
              f"{flags}")
    print(f"injected faults: {injector.injected}")

    # train -> serve hot-swap: only validated version bumps install
    init_banks = [jax.tree.map(np.asarray, ch.last_good)
                  for ch in collab.channels]
    eng = ServeEngine(cfg, params, slots=2, max_len=64,
                      user_adapters=init_banks)
    eng.bank_versions[:] = 0
    n = publish_banks(eng, collab.channels)
    print(f"\nserve engine: installed {n} validated bank(s); "
          f"versions now {eng.bank_versions.tolist()} "
          f"(rejected: {eng.stats['bank_rejected']})")
    for user in range(2):
        req = Request(rid=user, user=user,
                      prompt=np.arange(8) % cfg.vocab_size, max_new=8)
        eng.submit(req)
    eng.run_until_idle()
    for req in eng.finished:
        print(f"user {req.user} -> {req.out}  ({req.status})")


if __name__ == "__main__":
    main()
