"""LR schedules: linear warmup + {linear, cosine, const} decay (paper Table 5
uses linear decay with 5% warmup)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup_decay(base_lr: float, total_steps: int, warmup_frac: float = 0.05):
    warmup = max(1, int(total_steps * warmup_frac))

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        w = jnp.minimum(step / warmup, 1.0)
        decay = jnp.clip((total_steps - step) / max(1, total_steps - warmup), 0.0, 1.0)
        return base_lr * w * decay

    return fn


def cosine_warmup(base_lr: float, total_steps: int, warmup_frac: float = 0.05,
                  final_frac: float = 0.0):
    warmup = max(1, int(total_steps * warmup_frac))

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        w = jnp.minimum(step / warmup, 1.0)
        t = jnp.clip((step - warmup) / max(1, total_steps - warmup), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return base_lr * w * cos

    return fn


def const(base_lr: float):
    return lambda step: jnp.asarray(base_lr, jnp.float32)


def make(name: str, base_lr: float, total_steps: int, warmup_frac: float = 0.05):
    if name == "linear":
        return linear_warmup_decay(base_lr, total_steps, warmup_frac)
    if name == "cosine":
        return cosine_warmup(base_lr, total_steps, warmup_frac)
    if name == "const":
        return const(base_lr)
    raise ValueError(name)
