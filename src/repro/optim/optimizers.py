"""Optimizers from scratch (no optax in this environment).

API (optax-like):
    opt = adamw(lr=..., ...)          # lr may be a float or a schedule fn(step)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jax.Array], jax.Array]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]


def _lr_at(lr, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * factor).astype(g.dtype),
                        grads)


def sgd(lr, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mu"] = jax.tree.map(
                lambda p: jnp.zeros_like(p, jnp.float32), params)
        return state

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        if momentum:
            mu = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state["mu"], grads)
            if nesterov:
                upd = jax.tree.map(
                    lambda m, g: -(lr_t * (momentum * m + g.astype(jnp.float32))),
                    mu, grads)
            else:
                upd = jax.tree.map(lambda m: -lr_t * m, mu)
            return upd, {"step": step, "mu": mu}
        upd = jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32), grads)
        return upd, {"step": step}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return -lr_t * u

        return (jax.tree.map(upd, m, v, params),
                {"step": step, "m": m, "v": v})

    return Optimizer(init, update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates)


def make(name: str, lr, *, weight_decay=0.0, b1=0.9, b2=0.999, eps=1e-8,
         momentum=0.9) -> Optimizer:
    if name == "adamw":
        return adamw(lr, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay)
    if name == "sgd":
        return sgd(lr, momentum=momentum)
    raise ValueError(name)
