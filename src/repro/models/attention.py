"""GQA attention with full/local-window masking, RoPE, optional QK-norm and logit
softcap (gemma2). Works in three modes: train (full causal), prefill (causal +
returns KV for the cache) and decode (one new token against a cache).

The inner SDPA is routed through ``repro.kernels.ops.sdpa`` so the Pallas flash
kernel can replace the jnp reference on TPU without touching model code.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.kernels import ops as kernel_ops
from repro.models import layers as L

Array = jax.Array


def attn_init(key: Array, d_model: int, n_heads: int, n_kv: int, d_head: int,
              dtype, *, qk_norm: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "q": L.dense_init(ks[0], d_model, n_heads * d_head, dtype),
        "k": L.dense_init(ks[1], d_model, n_kv * d_head, dtype),
        "v": L.dense_init(ks[2], d_model, n_kv * d_head, dtype),
        "o": L.dense_init(ks[3], n_heads * d_head, d_model, dtype),
    }
    if qk_norm:
        p["q_norm"] = L.rmsnorm_init(d_head, dtype)
        p["k_norm"] = L.rmsnorm_init(d_head, dtype)
    return p


def _project_qkv(params: dict, x: Array, positions: Array, *, n_heads: int,
                 n_kv: int, d_head: int, rope_theta: float, qk_norm: bool,
                 tap_prefix: str, tap_ctx: tuple | None,
                 norm_eps: float = 1e-6) -> tuple[Array, Array, Array]:
    B, S, _ = x.shape
    q = L.dense(params["q"], x, tap=f"{tap_prefix}.q", tap_ctx=tap_ctx)
    k = L.dense(params["k"], x, tap=f"{tap_prefix}.k", tap_ctx=tap_ctx)
    v = L.dense(params["v"], x, tap=f"{tap_prefix}.v", tap_ctx=tap_ctx)
    q = constrain(q.reshape(B, S, n_heads, d_head), "batch", None, "model", None)
    k = constrain(k.reshape(B, S, n_kv, d_head), "batch", None, "model", None)
    v = constrain(v.reshape(B, S, n_kv, d_head), "batch", None, "model", None)
    if qk_norm:
        q = L.rmsnorm(params["q_norm"], q, eps=norm_eps)
        k = L.rmsnorm(params["k_norm"], k, eps=norm_eps)
    q = L.apply_rope(q, positions, rope_theta)
    k = L.apply_rope(k, positions, rope_theta)
    return q, k, v


def attention(params: dict, x: Array, positions: Array, *, n_heads: int,
              n_kv: int, d_head: int, rope_theta: float = 1e4,
              window: int | None = None, softcap: float | None = None,
              qk_norm: bool = False, tap_prefix: str = "attn",
              tap_ctx: tuple | None = None) -> Array:
    """Full-sequence causal attention (train / prefill compute path)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, positions, n_heads=n_heads, n_kv=n_kv,
                           d_head=d_head, rope_theta=rope_theta, qk_norm=qk_norm,
                           tap_prefix=tap_prefix, tap_ctx=tap_ctx)
    o = kernel_ops.sdpa(q, k, v, q_positions=positions, kv_positions=positions,
                        causal=True, window=window, softcap=softcap)
    o = constrain(o, "batch", None, "model", None).reshape(B, S, n_heads * d_head)
    y = L.dense(params["o"], o, tap=f"{tap_prefix}.o", tap_ctx=tap_ctx)
    return constrain(y, "batch", None, None)


def attention_prefill(params: dict, x: Array, positions: Array, *, n_heads: int,
                      n_kv: int, d_head: int, rope_theta: float = 1e4,
                      window: int | None = None, softcap: float | None = None,
                      qk_norm: bool = False, tap_prefix: str = "attn",
                      tap_ctx: tuple | None = None) -> tuple[Array, Array, Array]:
    """Like ``attention`` but also returns (k, v) to seed the decode cache."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, positions, n_heads=n_heads, n_kv=n_kv,
                           d_head=d_head, rope_theta=rope_theta, qk_norm=qk_norm,
                           tap_prefix=tap_prefix, tap_ctx=tap_ctx)
    o = kernel_ops.sdpa(q, k, v, q_positions=positions, kv_positions=positions,
                        causal=True, window=window, softcap=softcap)
    o = constrain(o, "batch", None, "model", None).reshape(B, S, n_heads * d_head)
    y = L.dense(params["o"], o, tap=f"{tap_prefix}.o", tap_ctx=tap_ctx)
    return constrain(y, "batch", None, None), k, v


def attention_decode(params: dict, x: Array, k_cache: Array, v_cache: Array,
                     positions: Array, *, n_heads: int, n_kv: int, d_head: int,
                     rope_theta: float = 1e4, window: int | None = None,
                     softcap: float | None = None, qk_norm: bool = False,
                     tap_prefix: str = "attn", tap_ctx: tuple | None = None,
                     live: Array | None = None) -> tuple[Array, Array, Array]:
    """One-token decode step.

    x: (B, 1, d_model); k_cache/v_cache: (B, Smax, K, Dh); positions: (B,) current
    write positions (number of tokens already in the cache for each row).
    ``live``: optional (B,) slot mask — dead rows' attention output is zeroed
    (their cache writes are reverted by the caller; see model._mask_cache_rows).
    Returns (y, new_k_cache, new_v_cache).
    """
    B, S1, _ = x.shape
    assert S1 == 1
    q, k, v = _project_qkv(params, x, positions[:, None], n_heads=n_heads,
                           n_kv=n_kv, d_head=d_head, rope_theta=rope_theta,
                           qk_norm=qk_norm, tap_prefix=tap_prefix, tap_ctx=tap_ctx)

    # Scatter the new k/v into the cache at per-row positions (vmap over batch).
    k_cache = jax.vmap(lambda c, n, p: jax.lax.dynamic_update_slice_in_dim(
        c, n, p, axis=0))(k_cache, k, positions)
    v_cache = jax.vmap(lambda c, n, p: jax.lax.dynamic_update_slice_in_dim(
        c, n, p, axis=0))(v_cache, v, positions)

    o = kernel_ops.sdpa_decode(q, k_cache, v_cache, positions, live=live,
                               window=window, softcap=softcap)
    o = o.reshape(B, 1, n_heads * d_head)
    y = L.dense(params["o"], o, tap=f"{tap_prefix}.o", tap_ctx=tap_ctx)
    return y, k_cache, v_cache
