"""GQA attention with full/local-window masking, RoPE, optional QK-norm and logit
softcap (gemma2). Works in three modes: train (full causal), prefill (causal +
returns KV for the cache) and decode (one new token against a cache).

The inner SDPA is routed through ``repro.kernels.ops.sdpa`` so the Pallas flash
kernel can replace the jnp reference on TPU without touching model code.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.kernels import ops as kernel_ops
from repro.models import layers as L

Array = jax.Array


def attn_init(key: Array, d_model: int, n_heads: int, n_kv: int, d_head: int,
              dtype, *, qk_norm: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "q": L.dense_init(ks[0], d_model, n_heads * d_head, dtype),
        "k": L.dense_init(ks[1], d_model, n_kv * d_head, dtype),
        "v": L.dense_init(ks[2], d_model, n_kv * d_head, dtype),
        "o": L.dense_init(ks[3], n_heads * d_head, d_model, dtype),
    }
    if qk_norm:
        p["q_norm"] = L.rmsnorm_init(d_head, dtype)
        p["k_norm"] = L.rmsnorm_init(d_head, dtype)
    return p


def _project_qkv(params: dict, x: Array, positions: Array, *, n_heads: int,
                 n_kv: int, d_head: int, rope_theta: float, qk_norm: bool,
                 tap_prefix: str, tap_ctx: tuple | None,
                 norm_eps: float = 1e-6) -> tuple[Array, Array, Array]:
    B, S, _ = x.shape
    q = L.dense(params["q"], x, tap=f"{tap_prefix}.q", tap_ctx=tap_ctx)
    k = L.dense(params["k"], x, tap=f"{tap_prefix}.k", tap_ctx=tap_ctx)
    v = L.dense(params["v"], x, tap=f"{tap_prefix}.v", tap_ctx=tap_ctx)
    q = constrain(q.reshape(B, S, n_heads, d_head), "batch", None, "model", None)
    k = constrain(k.reshape(B, S, n_kv, d_head), "batch", None, "model", None)
    v = constrain(v.reshape(B, S, n_kv, d_head), "batch", None, "model", None)
    if qk_norm:
        q = L.rmsnorm(params["q_norm"], q, eps=norm_eps)
        k = L.rmsnorm(params["k_norm"], k, eps=norm_eps)
    q = L.apply_rope(q, positions, rope_theta)
    k = L.apply_rope(k, positions, rope_theta)
    return q, k, v


def attention(params: dict, x: Array, positions: Array, *, n_heads: int,
              n_kv: int, d_head: int, rope_theta: float = 1e4,
              window: int | None = None, softcap: float | None = None,
              qk_norm: bool = False, tap_prefix: str = "attn",
              tap_ctx: tuple | None = None) -> Array:
    """Full-sequence causal attention (train / prefill compute path)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, positions, n_heads=n_heads, n_kv=n_kv,
                           d_head=d_head, rope_theta=rope_theta, qk_norm=qk_norm,
                           tap_prefix=tap_prefix, tap_ctx=tap_ctx)
    o = kernel_ops.sdpa(q, k, v, q_positions=positions, kv_positions=positions,
                        causal=True, window=window, softcap=softcap)
    o = constrain(o, "batch", None, "model", None).reshape(B, S, n_heads * d_head)
    y = L.dense(params["o"], o, tap=f"{tap_prefix}.o", tap_ctx=tap_ctx)
    return constrain(y, "batch", None, None)


def attention_prefill(params: dict, x: Array, positions: Array, *, n_heads: int,
                      n_kv: int, d_head: int, rope_theta: float = 1e4,
                      window: int | None = None, softcap: float | None = None,
                      qk_norm: bool = False, tap_prefix: str = "attn",
                      tap_ctx: tuple | None = None) -> tuple[Array, Array, Array]:
    """Like ``attention`` but also returns (k, v) to seed the decode cache."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, positions, n_heads=n_heads, n_kv=n_kv,
                           d_head=d_head, rope_theta=rope_theta, qk_norm=qk_norm,
                           tap_prefix=tap_prefix, tap_ctx=tap_ctx)
    o = kernel_ops.sdpa(q, k, v, q_positions=positions, kv_positions=positions,
                        causal=True, window=window, softcap=softcap)
    o = constrain(o, "batch", None, "model", None).reshape(B, S, n_heads * d_head)
    y = L.dense(params["o"], o, tap=f"{tap_prefix}.o", tap_ctx=tap_ctx)
    return constrain(y, "batch", None, None), k, v


def attention_decode(params: dict, x: Array, k_cache: Array, v_cache: Array,
                     positions: Array, *, n_heads: int, n_kv: int, d_head: int,
                     rope_theta: float = 1e4, window: int | None = None,
                     softcap: float | None = None, qk_norm: bool = False,
                     tap_prefix: str = "attn", tap_ctx: tuple | None = None,
                     live: Array | None = None,
                     block_table: Array | None = None,
                     ring: bool = False) -> tuple[Array, Array, Array]:
    """Incremental step: write ``c`` new tokens into the cache, attend causally
    against everything written so far. ``c == 1`` is the decode tick; ``c > 1``
    is one chunk of a chunked prefill (Sarathi-style — the chunk attends to all
    previous chunks through the cache, which full-sequence prefill cannot do).

    x: (B, c, d_model); positions: (B,) start position of the chunk per row
    (= number of tokens already in the cache). Three cache layouts:

    - dense (default): k/v_cache (B, Smax, K, Dh). Writes at positions
      [pos, pos + c); out-of-range positions (padded chunk tails near the
      horizon) are dropped, never clamped into earlier rows.
    - paged (``block_table`` (B, max_blocks) given): k/v_cache is the shared
      pool (n_blocks, block, K, Dh); position p lives in pool block
      ``table[b, p // block]`` at offset ``p % block``. Non-live rows and
      positions beyond the table map to block id n_blocks and are dropped at
      the scatter (a shared pool has no per-slot rows to revert afterwards).
    - ring (``ring=True``; pairs local-window layers under the paged layout):
      k/v_cache (B, W_ring, K, Dh) holds only the last W_ring positions;
      position p lives at ``p % W_ring``. Requires
      W_ring >= window + c - 1 so a chunk's earliest query still sees its full
      local window. Reads reorder the ring by ascending absolute position
      (see ref.sdpa_decode_ring) to keep summation order — and hence bits —
      identical to the dense layout.

    ``live``: optional (B,) slot mask — dead rows' attention output is zeroed;
    their dense/ring cache writes are reverted by the caller
    (model._mask_cache_rows) while paged writes are index-dropped here.
    Returns (y, new_k_cache, new_v_cache).
    """
    B, c, _ = x.shape
    pos2d = positions[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    q, k, v = _project_qkv(params, x, pos2d, n_heads=n_heads,
                           n_kv=n_kv, d_head=d_head, rope_theta=rope_theta,
                           qk_norm=qk_norm, tap_prefix=tap_prefix, tap_ctx=tap_ctx)

    if block_table is not None:
        n_blocks, bs = k_cache.shape[0], k_cache.shape[1]
        blk = jnp.take_along_axis(block_table,
                                  jnp.clip(pos2d // bs, 0,
                                           block_table.shape[1] - 1), axis=1)
        ok = pos2d < block_table.shape[1] * bs
        if live is not None:
            ok = ok & live[:, None]
        blk = jnp.where(ok, blk, n_blocks)          # OOB block id -> dropped
        off = pos2d % bs
        k_cache = k_cache.at[blk, off].set(k, mode="drop")
        v_cache = v_cache.at[blk, off].set(v, mode="drop")
        o = kernel_ops.sdpa_decode_paged(q, k_cache, v_cache, positions,
                                         block_table, live=live, window=window,
                                         softcap=softcap)
    elif ring:
        w_ring = k_cache.shape[1]
        b_idx = jnp.arange(B, dtype=jnp.int32)[:, None]
        k_cache = k_cache.at[b_idx, pos2d % w_ring].set(k)
        v_cache = v_cache.at[b_idx, pos2d % w_ring].set(v)
        o = kernel_ops.sdpa_decode_ring(q, k_cache, v_cache, positions,
                                        live=live, window=window,
                                        softcap=softcap)
    else:
        if c == 1:
            # keep the single-token decode write as a dynamic slice (the
            # compiled serving decode path) — positions stay < Smax here.
            k_cache = jax.vmap(lambda cc, n, p: jax.lax.dynamic_update_slice_in_dim(
                cc, n, p, axis=0))(k_cache, k, positions)
            v_cache = jax.vmap(lambda cc, n, p: jax.lax.dynamic_update_slice_in_dim(
                cc, n, p, axis=0))(v_cache, v, positions)
        else:
            # chunk writes scatter per position so a padded chunk tail that
            # crosses the horizon is *dropped* (dynamic_update_slice would
            # clamp the window back over real KV).
            Smax = k_cache.shape[1]
            b_idx = jnp.arange(B, dtype=jnp.int32)[:, None]
            tgt = jnp.where(pos2d < Smax, pos2d, Smax)
            k_cache = k_cache.at[b_idx, tgt].set(k, mode="drop")
            v_cache = v_cache.at[b_idx, tgt].set(v, mode="drop")
        o = kernel_ops.sdpa_decode(q, k_cache, v_cache, positions, live=live,
                                   window=window, softcap=softcap)
    o = o.reshape(B, c, n_heads * d_head)
    y = L.dense(params["o"], o, tap=f"{tap_prefix}.o", tap_ctx=tap_ctx)
    return y, k_cache, v_cache
