"""Residual blocks for every architecture family.

Block kinds:
- "attn"  : pre-norm attention + gated-MLP (llama/mistral style); gemma2 adds
            post-norms, GeGLU, softcap, local/global flavors.
- "moe"   : attention + routed-expert FFN (qwen3-moe, dbrx).
- "ssm"   : Mamba2 mixer only (norm + SSD block), no FFN (mamba2 arch).
- zamba2's shared attention block is an "attn" block applied at multiple depths
  with shared params (see model.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S

Array = jax.Array


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def attn_block_init(cfg: ModelConfig, key: Array, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": L.rmsnorm_init(cfg.d_model, dtype),
        "attn": A.attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                            cfg.d_head, dtype, qk_norm=cfg.qk_norm),
        "ln2": L.rmsnorm_init(cfg.d_model, dtype),
    }
    if cfg.n_experts:
        p["moe"] = M.moe_init(k2, cfg.d_model, cfg.n_experts, cfg.d_expert, dtype)
    else:
        p["mlp"] = L.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
    if cfg.post_norm:
        p["post_ln1"] = L.rmsnorm_init(cfg.d_model, dtype)
        p["post_ln2"] = L.rmsnorm_init(cfg.d_model, dtype)
    return p


def ssm_block_init(cfg: ModelConfig, key: Array, dtype) -> dict:
    return {
        "ln": L.rmsnorm_init(cfg.d_model, dtype),
        "ssm": S.ssm_init(key, cfg.d_model, dtype, expand=cfg.ssm_expand,
                          headdim=cfg.ssm_headdim, state=cfg.ssm_state,
                          d_conv=cfg.ssm_conv),
    }


# ---------------------------------------------------------------------------
# full-sequence apply (train / prefill)
# ---------------------------------------------------------------------------

def _norm(cfg: ModelConfig, p, x):
    return L.rmsnorm(p, x, eps=cfg.norm_eps, plus_one=cfg.norm_plus_one)


def attn_block(cfg: ModelConfig, params: dict, x: Array, positions: Array, *,
               window: int | None, tap_prefix: str, tap_ctx: tuple | None,
               return_kv: bool = False):
    h = _norm(cfg, params["ln1"], x)
    kv = None
    kwargs = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.d_head,
                  rope_theta=cfg.rope_theta, window=window,
                  softcap=cfg.attn_softcap or None, qk_norm=cfg.qk_norm,
                  tap_prefix=f"{tap_prefix}.attn", tap_ctx=tap_ctx)
    if return_kv:
        h, k, v = A.attention_prefill(params["attn"], h, positions, **kwargs)
        kv = (k, v)
    else:
        h = A.attention(params["attn"], h, positions, **kwargs)
    if cfg.post_norm:
        h = _norm(cfg, params["post_ln1"], h)
    x = x + h

    h = _norm(cfg, params["ln2"], x)
    moe_aux = jnp.zeros((), jnp.float32)
    if cfg.n_experts:
        h, moe_aux = M.moe_block(params["moe"], h, top_k=cfg.moe_top_k,
                                 impl=cfg.moe_impl, group=cfg.moe_group,
                                 capacity_factor=cfg.capacity_factor)
    else:
        h = L.mlp(params["mlp"], h, act=cfg.act,
                  tap_prefix=f"{tap_prefix}.mlp", tap_ctx=tap_ctx)
    if cfg.post_norm:
        h = _norm(cfg, params["post_ln2"], h)
    x = x + h
    if return_kv:
        return x, moe_aux, kv
    return x, moe_aux


def ssm_block(cfg: ModelConfig, params: dict, x: Array, *, tap_prefix: str,
              tap_ctx: tuple | None, return_state: bool = False):
    h = _norm(cfg, params["ln"], x)
    out = S.ssm_block(params["ssm"], h, d_model=cfg.d_model,
                      expand=cfg.ssm_expand, headdim=cfg.ssm_headdim,
                      state=cfg.ssm_state, norm_eps=cfg.norm_eps,
                      chunk=cfg.ssd_chunk, tap_prefix=f"{tap_prefix}.ssm",
                      tap_ctx=tap_ctx, return_state=return_state)
    if return_state:
        y, state = out
        return x + y, state
    return x + out


# ---------------------------------------------------------------------------
# decode-step apply
# ---------------------------------------------------------------------------

def attn_block_decode(cfg: ModelConfig, params: dict, x: Array, k_cache: Array,
                      v_cache: Array, positions: Array, *, window: int | None,
                      tap_prefix: str, tap_ctx: tuple | None,
                      live: Array | None = None,
                      block_table: Array | None = None, ring: bool = False):
    h = _norm(cfg, params["ln1"], x)
    h, k_cache, v_cache = A.attention_decode(
        params["attn"], h, k_cache, v_cache, positions,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.d_head,
        rope_theta=cfg.rope_theta, window=window,
        softcap=cfg.attn_softcap or None, qk_norm=cfg.qk_norm,
        tap_prefix=f"{tap_prefix}.attn", tap_ctx=tap_ctx, live=live,
        block_table=block_table, ring=ring)
    if cfg.post_norm:
        h = _norm(cfg, params["post_ln1"], h)
    x = x + h
    h = _norm(cfg, params["ln2"], x)
    if cfg.n_experts:
        h, _ = M.moe_block(params["moe"], h, top_k=cfg.moe_top_k,
                           impl=cfg.moe_impl, group=cfg.moe_group,
                           capacity_factor=cfg.capacity_factor)
    else:
        h = L.mlp(params["mlp"], h, act=cfg.act,
                  tap_prefix=f"{tap_prefix}.mlp", tap_ctx=tap_ctx)
    if cfg.post_norm:
        h = _norm(cfg, params["post_ln2"], h)
    return x + h, k_cache, v_cache


def ssm_block_decode(cfg: ModelConfig, params: dict, x: Array, conv_state: Array,
                     ssm_state: Array, *, tap_prefix: str, tap_ctx: tuple | None):
    """Incremental ssm step. x: (B, 1, d) runs the single-token recurrence;
    x: (B, c, d) runs one prefill chunk through the full-sequence block with
    chunk-boundary (conv, ssd) state carried in and out — exact-length
    semantics, no padding ever touches the recurrent state."""
    h = _norm(cfg, params["ln"], x)
    if x.shape[1] > 1:
        y, st = S.ssm_block(
            params["ssm"], h, d_model=cfg.d_model, expand=cfg.ssm_expand,
            headdim=cfg.ssm_headdim, state=cfg.ssm_state, norm_eps=cfg.norm_eps,
            chunk=cfg.ssd_chunk, tap_prefix=f"{tap_prefix}.ssm",
            tap_ctx=tap_ctx, init_state=ssm_state, conv_state=conv_state,
            return_state=True)
        return x + y, st["conv"], st["ssm"]
    y, conv_state, ssm_state = S.ssm_decode_step(
        params["ssm"], h, conv_state, ssm_state, d_model=cfg.d_model,
        expand=cfg.ssm_expand, headdim=cfg.ssm_headdim, state=cfg.ssm_state,
        norm_eps=cfg.norm_eps, tap_prefix=f"{tap_prefix}.ssm", tap_ctx=tap_ctx)
    return x + y, conv_state, ssm_state
