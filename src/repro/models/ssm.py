"""Mamba2 (SSD) block: fused in_proj -> causal depthwise conv -> SSD -> gated
norm -> out_proj. Train path uses the chunked SSD scan; decode path carries
(conv_state, ssm_state).

ColA taps: ``<prefix>.in`` (d_model -> d_in_proj) and ``<prefix>.out``
(d_inner -> d_model) — plain Dense sites, mergeable per Prop 2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.kernels import ops as kernel_ops
from repro.models import layers as L

Array = jax.Array


def ssm_dims(d_model: int, *, expand: int = 2, headdim: int = 64,
             state: int = 128) -> dict:
    d_inner = expand * d_model
    nheads = d_inner // headdim
    return dict(d_inner=d_inner, nheads=nheads, headdim=headdim, state=state)


def ssm_init(key: Array, d_model: int, dtype, *, expand: int = 2,
             headdim: int = 64, state: int = 128, d_conv: int = 4) -> dict:
    dims = ssm_dims(d_model, expand=expand, headdim=headdim, state=state)
    di, H, N = dims["d_inner"], dims["nheads"], dims["state"]
    d_in_proj = 2 * di + 2 * N + H   # [z, x, B, C, dt]
    conv_ch = di + 2 * N
    ks = jax.random.split(key, 4)
    dt = jnp.exp(jax.random.uniform(ks[2], (H,), jnp.float32) *
                 (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))   # inverse softplus
    return {
        "in_proj": L.dense_init(ks[0], d_model, d_in_proj, dtype),
        "out_proj": L.dense_init(ks[1], di, d_model, dtype),
        "conv_w": (jax.random.normal(ks[3], (d_conv, conv_ch), jnp.float32)
                   * (d_conv ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),     # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "norm": L.rmsnorm_init(di, dtype),
    }


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv. x: (B,S,C); w: (W,C)."""
    W = w.shape[0]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    xf = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    for i in range(W):   # W = 4: unrolled shifts
        shift = W - 1 - i
        xi = jnp.pad(xf, ((0, 0), (shift, 0), (0, 0)))[:, :xf.shape[1]]
        out = out + xi * wf[i]
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _split_proj(zxbcdt: Array, di: int, N: int, H: int):
    z = zxbcdt[..., :di]
    x = zxbcdt[..., di:2 * di]
    Bm = zxbcdt[..., 2 * di:2 * di + N]
    Cm = zxbcdt[..., 2 * di + N:2 * di + 2 * N]
    dt = zxbcdt[..., 2 * di + 2 * N:]
    return z, x, Bm, Cm, dt


def ssm_block(params: dict, u: Array, *, d_model: int, expand: int = 2,
              headdim: int = 64, state: int = 128, norm_eps: float = 1e-5,
              chunk: int = 128, tap_prefix: str = "ssm",
              tap_ctx: tuple | None = None,
              init_state: Array | None = None,
              conv_state: Array | None = None,
              return_state: bool = False):
    """Full-sequence Mamba2 block. u: (B, S, d_model).

    ``conv_state``/``init_state`` carry chunk-boundary state for chunked
    prefill: passing the (B, W-1, C) raw-input tail and (B, H, P, N) SSD state
    of the previous chunk makes this call compute exactly the continuation —
    the conv output of every position sums the same W raw inputs in the same
    order as one full-sequence call (zero conv_state reproduces the
    zero-padded start bit-for-bit), and the SSD scan folds the carried state
    through ``init_state``.
    """
    dims = ssm_dims(d_model, expand=expand, headdim=headdim, state=state)
    di, H, P, N = dims["d_inner"], dims["nheads"], dims["headdim"], dims["state"]
    Bsz, S, _ = u.shape

    zxbcdt = L.dense(params["in_proj"], u, tap=f"{tap_prefix}.in", tap_ctx=tap_ctx)
    z, x, Bm, Cm, dt = _split_proj(zxbcdt, di, N, H)
    xbc_raw = jnp.concatenate([x, Bm, Cm], axis=-1)
    W = params["conv_w"].shape[0]
    if conv_state is not None:
        # chunk continuation: convolve over [prev tail ; this chunk] and keep
        # only this chunk's outputs; the new tail comes from the extended
        # history (exact even when S < W - 1).
        hist = jnp.concatenate([conv_state.astype(xbc_raw.dtype), xbc_raw],
                               axis=1)                  # (B, W-1+S, C)
        tail = hist[:, -(W - 1):]
        xbc = jax.nn.silu(_causal_conv(hist, params["conv_w"],
                                       params["conv_b"])[:, W - 1:])
    else:
        # conv tail = raw inputs of the last (W-1) positions, padded if
        # S < W-1; this seeds the decode conv state after a prefill.
        tail = xbc_raw[:, -(W - 1):]
        if tail.shape[1] < W - 1:
            tail = jnp.pad(tail, ((0, 0), (W - 1 - tail.shape[1], 0), (0, 0)))
        xbc = jax.nn.silu(_causal_conv(xbc_raw, params["conv_w"],
                                       params["conv_b"]))
    x, Bm, Cm = xbc[..., :di], xbc[..., di:di + N], xbc[..., di + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B,S,H)
    a = -jnp.exp(params["A_log"])
    xh = constrain(x.reshape(Bsz, S, H, P), "batch", None, "model", None)
    y, final_state = kernel_ops.ssd(xh, dt, a, Bm, Cm,
                                    params["D"], init_state, chunk=chunk)
    y = constrain(y, "batch", None, "model", None).reshape(Bsz, S, di)
    y = L.rmsnorm(params["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                  eps=norm_eps)
    out = L.dense(params["out_proj"], y, tap=f"{tap_prefix}.out", tap_ctx=tap_ctx)
    if return_state:
        return out, {"ssm": final_state, "conv": tail}
    return out


def ssm_decode_step(params: dict, u: Array, conv_state: Array, ssm_state: Array,
                    *, d_model: int, expand: int = 2, headdim: int = 64,
                    state: int = 128, norm_eps: float = 1e-5,
                    tap_prefix: str = "ssm", tap_ctx: tuple | None = None):
    """One-token decode. u: (B, 1, d_model); conv_state: (B, W-1, C);
    ssm_state: (B, H, P, N). Returns (out, conv_state, ssm_state)."""
    dims = ssm_dims(d_model, expand=expand, headdim=headdim, state=state)
    di, H, P, N = dims["d_inner"], dims["nheads"], dims["headdim"], dims["state"]
    Bsz = u.shape[0]

    zxbcdt = L.dense(params["in_proj"], u[:, 0], tap=f"{tap_prefix}.in",
                     tap_ctx=tap_ctx)                      # (B, d_in_proj)
    z, x, Bm, Cm, dt = _split_proj(zxbcdt, di, N, H)
    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)            # (B, C)
    # conv over [conv_state ; xbc]
    w = params["conv_w"].astype(jnp.float32)               # (W, C)
    hist = jnp.concatenate([conv_state.astype(jnp.float32),
                            xbc.astype(jnp.float32)[:, None]], axis=1)  # (B,W,C)
    conv_out = jnp.einsum("bwc,wc->bc", hist, w) + params["conv_b"].astype(jnp.float32)
    conv_out = jax.nn.silu(conv_out).astype(u.dtype)
    new_conv_state = hist[:, 1:].astype(conv_state.dtype)
    x, Bm, Cm = conv_out[..., :di], conv_out[..., di:di + N], conv_out[..., di + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B,H)
    a = -jnp.exp(params["A_log"])
    y, ssm_state = kernel_ops.ssd_decode_step(
        x.reshape(Bsz, H, P), dt, a, Bm, Cm, params["D"], ssm_state)
    y = y.reshape(Bsz, di)
    y = L.rmsnorm(params["norm"],
                  y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                  eps=norm_eps)
    out = L.dense(params["out_proj"], y, tap=f"{tap_prefix}.out", tap_ctx=tap_ctx)
    return out[:, None], new_conv_state, ssm_state


def ssm_state_shapes(d_model: int, batch: int, *, expand: int = 2,
                     headdim: int = 64, state: int = 128, d_conv: int = 4) -> dict:
    dims = ssm_dims(d_model, expand=expand, headdim=headdim, state=state)
    di, H, P, N = dims["d_inner"], dims["nheads"], dims["headdim"], dims["state"]
    return {
        "conv": (batch, d_conv - 1, di + 2 * N),
        "ssm": (batch, H, P, N),
    }
