"""LM assembly for every architecture family.

Layer plans
-----------
- "uniform": one scanned stack of identical blocks ("layers.*" taps) —
  dense, moe, ssm families.
- "pairs":   gemma2's alternating local/global — two scanned stacks
  ("layers_a.*" = local, "layers_b.*" = global), scanned jointly over pairs.
- "hybrid":  zamba2 — 14 unrolled segments, each = shared attention block
  ("shared.*" taps, one param set reused at every depth) + a scanned slice of
  the 81 Mamba2 layers ("layers.*" taps).

Entry points: init, forward, loss_fn, prefill, decode_step, cache_specs,
init_cache, tap_sites.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro import flags
from repro.configs.base import ModelConfig
from repro.core.taps import ColaSpec, TapSite
from repro.distributed.sharding import constrain
from repro.models import blocks as B
from repro.models import layers as L
from repro.models import ssm as S
from repro.utils import canonical_dtype

Array = jax.Array


# ---------------------------------------------------------------------------
# layer plan
# ---------------------------------------------------------------------------

def layer_plan(cfg: ModelConfig):
    if cfg.family == "hybrid":
        every = cfg.shared_attn_every
        starts = list(range(0, cfg.n_layers, every))
        segs = [(s, min(s + every, cfg.n_layers) - s) for s in starts]
        return ("hybrid", segs)
    if cfg.family == "dense" and cfg.attn_pattern == "local_global":
        assert cfg.n_layers % 2 == 0
        return ("pairs", cfg.n_layers // 2)
    kind = "ssm" if cfg.family == "ssm" else "attn"
    return ("uniform", kind)


def _tree_slice(tree, start, end):
    return jax.tree.map(lambda a: a[start:end], tree)


def _subvars(d: dict | None, prefix: str) -> dict:
    if not d:
        return {}
    return {k: v for k, v in d.items() if k.startswith(prefix + ".")}


def _checkpointed(cfg: ModelConfig, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


# ---------------------------------------------------------------------------
# tap sites
# ---------------------------------------------------------------------------

def _attn_sites(cfg: ModelConfig, prefix: str, stacked: int) -> dict[str, TapSite]:
    sites = {}
    for nm, din, dout in [
        ("attn.q", cfg.d_model, cfg.n_heads * cfg.d_head),
        ("attn.k", cfg.d_model, cfg.n_kv_heads * cfg.d_head),
        ("attn.v", cfg.d_model, cfg.n_kv_heads * cfg.d_head),
        ("attn.o", cfg.n_heads * cfg.d_head, cfg.d_model),
    ]:
        full = f"{prefix}.{nm}"
        sites[full] = TapSite(full, din, dout, stacked)
    if cfg.d_ff:
        for nm, din, dout in [
            ("mlp.gate", cfg.d_model, cfg.d_ff),
            ("mlp.up", cfg.d_model, cfg.d_ff),
            ("mlp.down", cfg.d_ff, cfg.d_model),
        ]:
            full = f"{prefix}.{nm}"
            sites[full] = TapSite(full, din, dout, stacked)
    return sites


def _ssm_sites(cfg: ModelConfig, prefix: str, stacked: int) -> dict[str, TapSite]:
    dims = S.ssm_dims(cfg.d_model, expand=cfg.ssm_expand,
                      headdim=cfg.ssm_headdim, state=cfg.ssm_state)
    d_in_proj = 2 * dims["d_inner"] + 2 * dims["state"] + dims["nheads"]
    return {
        f"{prefix}.ssm.in": TapSite(f"{prefix}.ssm.in", cfg.d_model, d_in_proj, stacked),
        f"{prefix}.ssm.out": TapSite(f"{prefix}.ssm.out", dims["d_inner"],
                                     cfg.d_model, stacked),
    }


def delta_shape(cfg: ModelConfig, site: TapSite, batch: int, seq: int) -> tuple:
    """Shape of the Mode-A injected delta for one tap. Stacked sites carry the
    layer axis; zamba2's shared block carries one slot per invocation (so each
    call site gets its own grad, per the chain rule over shared parameters)."""
    base = (batch, seq, site.d_out)
    if site.stacked:
        return (site.stacked,) + base
    if site.name.startswith("shared."):
        n_seg = len(layer_plan(cfg)[1])
        return (n_seg,) + base
    return base


def tap_sites(cfg: ModelConfig) -> dict[str, TapSite]:
    plan = layer_plan(cfg)
    if plan[0] == "uniform" and plan[1] == "attn":
        return _attn_sites(cfg, "layers", cfg.n_layers)
    if plan[0] == "uniform" and plan[1] == "ssm":
        return _ssm_sites(cfg, "layers", cfg.n_layers)
    if plan[0] == "pairs":
        sites = _attn_sites(cfg, "layers_a", plan[1])
        sites.update(_attn_sites(cfg, "layers_b", plan[1]))
        return sites
    if plan[0] == "hybrid":
        sites = _ssm_sites(cfg, "layers", cfg.n_layers)
        sites.update(_attn_sites(cfg, "shared", 0))
        return sites
    raise ValueError(plan)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stacked_init(n: int, fn, key):
    return jax.vmap(fn)(jax.random.split(key, n))


def init(cfg: ModelConfig, key: Array) -> dict:
    dt = canonical_dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {}

    # embeddings / head
    if cfg.n_codebooks:
        emb = (jax.random.normal(keys[0], (cfg.n_codebooks, cfg.vocab_size,
                                           cfg.d_model), jnp.float32) * 0.02)
        params["embed"] = {"emb": emb.astype(dt)}
        params["lm_head"] = L.dense_init(keys[1], cfg.d_model,
                                         cfg.n_codebooks * cfg.vocab_size, dt)
    elif cfg.embed_input:
        params["unembed"] = L.embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt)
    else:
        params["embed"] = L.embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt)
        if not cfg.tie_embeddings:
            params["lm_head"] = L.dense_init(keys[1], cfg.d_model, cfg.vocab_size, dt)

    plan = layer_plan(cfg)
    if plan[0] == "uniform":
        blk = (functools.partial(B.attn_block_init, cfg, dtype=dt)
               if plan[1] == "attn"
               else functools.partial(B.ssm_block_init, cfg, dtype=dt))
        params["layers"] = _stacked_init(cfg.n_layers, lambda k: blk(key=k), keys[2])
    elif plan[0] == "pairs":
        half = plan[1]
        params["layers_a"] = _stacked_init(
            half, lambda k: B.attn_block_init(cfg, k, dt), keys[2])
        params["layers_b"] = _stacked_init(
            half, lambda k: B.attn_block_init(cfg, k, dt), keys[3])
    else:  # hybrid
        params["layers"] = _stacked_init(
            cfg.n_layers, lambda k: B.ssm_block_init(cfg, k, dt), keys[2])
        params["shared"] = B.attn_block_init(cfg, keys[3], dt)

    params["final_norm"] = L.rmsnorm_init(cfg.d_model, dt)
    return params


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ModelConfig, params: dict, batch: dict) -> Array:
    cdt = canonical_dtype(cfg.compute_dtype)
    if cfg.embed_input:
        x = batch["embeds"].astype(cdt)
    elif cfg.n_codebooks:
        toks = batch["tokens"]                      # (B, S, CB)
        emb = params["embed"]["emb"]                # (CB, V, d)
        x = jnp.zeros(toks.shape[:2] + (cfg.d_model,), cdt)
        for cb in range(cfg.n_codebooks):
            x = x + emb[cb].astype(cdt)[toks[..., cb]]
    else:
        x = params["embed"]["emb"].astype(cdt)[batch["tokens"]]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cdt)
    return constrain(x, "batch", None, None)


def head_logits(cfg: ModelConfig, params: dict, h: Array) -> Array:
    """h: (..., d) -> logits (..., V) or (..., CB, V) for musicgen."""
    if cfg.n_codebooks:
        logits = h @ params["lm_head"]["w"].astype(h.dtype)
        logits = logits.reshape(h.shape[:-1] + (cfg.n_codebooks, cfg.vocab_size))
    elif cfg.embed_input:
        logits = h @ params["unembed"]["emb"].astype(h.dtype).T
    elif cfg.tie_embeddings:
        logits = h @ params["embed"]["emb"].astype(h.dtype).T
    else:
        logits = h @ params["lm_head"]["w"].astype(h.dtype)
    if cfg.final_softcap:
        logits = (jnp.tanh(logits.astype(jnp.float32) / cfg.final_softcap)
                  * cfg.final_softcap).astype(logits.dtype)
    if logits.ndim == 3:
        logits = constrain(logits, "batch", None, "model")
    elif logits.ndim == 4:   # musicgen (B, S, CB, V)
        logits = constrain(logits, "batch", None, None, "model")
    return logits


# ---------------------------------------------------------------------------
# layer stacks — full sequence
# ---------------------------------------------------------------------------

def _scan_stack(cfg: ModelConfig, stack_params, x, positions, spec, adapters,
                deltas, *, kind: str, prefix: str, window_pattern=None,
                collect_kv: bool = False, collect_state: bool = False):
    """Scan a homogeneous stack. Returns (x, ys) with ys per-layer stacked aux."""
    ad = _subvars(adapters, prefix)
    de = _subvars(deltas, prefix)

    def compute(x, lp, ad_l, de_l):
        # sequence parallelism (Megatron-SP): the residual stream between
        # blocks lives sharded over the model axis; norms/adds run sharded and
        # GSPMD inserts the gather/scatter pair around attention/mlp. This also
        # shards the per-layer remat residuals model_axis-ways.
        x = constrain(x, "batch", "model", None)
        aux: dict = {}
        tap_ctx = (spec, ad_l, de_l, aux)
        y: dict = {}
        if kind == "attn":
            out = B.attn_block(cfg, lp, x, positions, window=None,
                               tap_prefix=prefix, tap_ctx=tap_ctx,
                               return_kv=collect_kv)
            if collect_kv:
                x, moe_aux, (k, v) = out
                y["k"], y["v"] = k, v
            else:
                x, moe_aux = out
            y["moe_aux"] = moe_aux
        else:
            out = B.ssm_block(cfg, lp, x, tap_prefix=prefix, tap_ctx=tap_ctx,
                              return_state=collect_state)
            if collect_state:
                x, st = out
                y["ssm"], y["conv"] = st["ssm"], st["conv"]
            else:
                x = out
            y["moe_aux"] = jnp.zeros((), jnp.float32)
        y["collected"] = aux
        return x, y

    body = _checkpointed(cfg, compute)

    def scan_body(x, xs):
        lp, ad_l, de_l = xs
        return body(x, lp, ad_l, de_l)

    return jax.lax.scan(scan_body, x, (stack_params, ad, de),
                        unroll=flags.scan_unroll())


def _scan_pairs(cfg: ModelConfig, params, x, positions, spec, adapters, deltas,
                *, collect_kv: bool = False):
    ad_a, de_a = _subvars(adapters, "layers_a"), _subvars(deltas, "layers_a")
    ad_b, de_b = _subvars(adapters, "layers_b"), _subvars(deltas, "layers_b")

    def compute(x, lp_a, lp_b, ada, dea, adb, deb):
        x = constrain(x, "batch", "model", None)   # sequence parallelism
        aux: dict = {}
        y: dict = {}
        out = B.attn_block(cfg, lp_a, x, positions, window=cfg.local_window,
                           tap_prefix="layers_a", tap_ctx=(spec, ada, dea, aux),
                           return_kv=collect_kv)
        if collect_kv:
            x, m1, (ka, va) = out
            y["ka"], y["va"] = ka, va
        else:
            x, m1 = out
        out = B.attn_block(cfg, lp_b, x, positions, window=None,
                           tap_prefix="layers_b", tap_ctx=(spec, adb, deb, aux),
                           return_kv=collect_kv)
        if collect_kv:
            x, m2, (kb, vb) = out
            y["kb"], y["vb"] = kb, vb
        else:
            x, m2 = out
        y["moe_aux"] = m1 + m2
        y["collected"] = aux
        return x, y

    body = _checkpointed(cfg, compute)

    def scan_body(x, xs):
        lp_a, lp_b, ada, dea, adb, deb = xs
        return body(x, lp_a, lp_b, ada, dea, adb, deb)

    return jax.lax.scan(scan_body, x,
                        (params["layers_a"], params["layers_b"],
                         ad_a, de_a, ad_b, de_b),
                        unroll=flags.scan_unroll())


def _run_hybrid(cfg: ModelConfig, params, x, positions, spec, adapters, deltas,
                *, collect_kv: bool = False, collect_state: bool = False):
    """Zamba2: unrolled segments of (shared attn block + mamba slice)."""
    _, segs = layer_plan(cfg)
    sh_ad = _subvars(adapters, "shared")
    sh_de = _subvars(deltas, "shared")   # leaves: (n_seg, B, S, d) per invocation
    seg_ys, shared_kvs, collected_shared = [], [], []
    for i, (start, ln) in enumerate(segs):
        aux: dict = {}
        sh_de_i = {k: v[i] for k, v in sh_de.items()}
        out = B.attn_block(cfg, params["shared"], x, positions, window=None,
                           tap_prefix="shared",
                           tap_ctx=(spec, sh_ad, sh_de_i, aux),
                           return_kv=collect_kv)
        if collect_kv:
            x, _, kv = out
            shared_kvs.append(kv)
        else:
            x, _ = out
        collected_shared.append(aux)
        seg_params = _tree_slice(params["layers"], start, start + ln)
        seg_ad = jax.tree.map(lambda a: a[start:start + ln],
                              _subvars(adapters, "layers"))
        seg_de = jax.tree.map(lambda a: a[start:start + ln],
                              _subvars(deltas, "layers"))
        x, ys = _scan_stack(cfg, seg_params, x, positions, spec,
                            {**seg_ad}, {**seg_de}, kind="ssm", prefix="layers",
                            collect_state=collect_state)
        seg_ys.append(ys)
    ys = jax.tree.map(lambda *a: jnp.concatenate(a, axis=0), *seg_ys)
    # shared-block collected taps: sum of hidden inputs is NOT meaningful; keep
    # them stacked per invocation: {tap: (n_seg, B, S, d)}
    if collected_shared and collected_shared[0]:
        stacked = {k: jnp.stack([c[k] for c in collected_shared])
                   for k in collected_shared[0]}
    else:
        stacked = {}
    out_aux = {"collected_shared": stacked}
    if collect_kv:
        out_aux["shared_k"] = jnp.stack([kv[0] for kv in shared_kvs])
        out_aux["shared_v"] = jnp.stack([kv[1] for kv in shared_kvs])
    return x, ys, out_aux


def hidden_states(cfg: ModelConfig, params: dict, batch: dict,
                  spec: ColaSpec | None = None, cola_vars: dict | None = None,
                  *, collect_kv: bool = False, collect_state: bool = False):
    """Run embedding + all layers. Returns (h, aux)."""
    adapters = (cola_vars or {}).get("adapters", {})
    deltas = (cola_vars or {}).get("deltas", {})
    x = embed_tokens(cfg, params, batch)
    Bz, Ssz = x.shape[0], x.shape[1]
    positions = jnp.arange(Ssz, dtype=jnp.int32)[None, :]
    plan = layer_plan(cfg)
    aux: dict[str, Any] = {}
    if plan[0] == "uniform":
        x, ys = _scan_stack(cfg, params["layers"], x, positions, spec, adapters,
                            deltas, kind=plan[1], prefix="layers",
                            collect_kv=collect_kv, collect_state=collect_state)
    elif plan[0] == "pairs":
        x, ys = _scan_pairs(cfg, params, x, positions, spec, adapters, deltas,
                            collect_kv=collect_kv)
    else:
        x, ys, extra = _run_hybrid(cfg, params, x, positions, spec, adapters,
                                   deltas, collect_kv=collect_kv,
                                   collect_state=collect_state)
        aux.update(extra)
    aux["moe_aux"] = jnp.mean(ys.pop("moe_aux"))
    aux["collected"] = ys.pop("collected")
    aux["stacked"] = ys    # kv / ssm-state per layer when requested
    x = L.rmsnorm(params["final_norm"], x, eps=cfg.norm_eps,
                  plus_one=cfg.norm_plus_one)
    return x, aux


def forward(cfg: ModelConfig, params: dict, batch: dict,
            spec: ColaSpec | None = None, cola_vars: dict | None = None):
    h, aux = hidden_states(cfg, params, batch, spec, cola_vars)
    return head_logits(cfg, params, h), aux


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def _ce(logits: Array, labels: Array) -> tuple[Array, Array]:
    """Sum of CE and count over valid (label >= 0) positions. f32 math."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, jnp.clip(labels, 0)[..., None],
                             axis=-1)[..., 0]
    valid = labels >= 0
    ce = jnp.where(valid, lse - ll, 0.0)
    return jnp.sum(ce), jnp.sum(valid)


def lm_loss(cfg: ModelConfig, params: dict, h: Array, labels: Array) -> Array:
    """CE from hidden states; optionally chunked over sequence so the full
    (B, S, V) logits tensor is never materialised (memory optimisation)."""
    Ssz = h.shape[1]
    if cfg.loss_chunk and Ssz % cfg.loss_chunk == 0 and Ssz > cfg.loss_chunk:
        nc = Ssz // cfg.loss_chunk
        hc = h.reshape(h.shape[0], nc, cfg.loss_chunk, h.shape[-1]).swapaxes(0, 1)
        yc = labels.reshape(labels.shape[0], nc, cfg.loss_chunk,
                            *labels.shape[2:]).swapaxes(0, 1)

        def body(carry, xs):
            hh, yy = xs
            s, n = _ce(head_logits(cfg, params, hh), yy)
            return (carry[0] + s, carry[1] + n), None

        (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                     (hc, yc), unroll=flags.scan_unroll())
        return tot / jnp.maximum(cnt, 1.0)
    s, n = _ce(head_logits(cfg, params, h), labels)
    return s / jnp.maximum(n, 1.0)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict,
            spec: ColaSpec | None = None, cola_vars: dict | None = None):
    h, aux = hidden_states(cfg, params, batch, spec, cola_vars)
    loss = lm_loss(cfg, params, h, batch["labels"])
    if cfg.n_experts:
        loss = loss + cfg.aux_loss_coef * aux["moe_aux"]
    return loss, aux


# ---------------------------------------------------------------------------
# caches / decode
# ---------------------------------------------------------------------------

def has_recurrent_state(cfg: ModelConfig) -> bool:
    """True when the decode cache contains recurrent (ssm/conv) state, which —
    unlike attention KV — cannot be seeded from a right-padded prefill batch
    (the final state folds in pad tokens)."""
    plan = layer_plan(cfg)
    return plan[0] == "hybrid" or (plan[0] == "uniform" and plan[1] == "ssm")


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, *,
                kv_layout: str = "dense", kv_blocks: int | None = None,
                kv_block: int = 16, ring_len: int | None = None) -> dict:
    """Decode-cache leaf specs.

    ``kv_layout="dense"``: every attention stack gets a (n, batch, max_len, K,
    Dh) slot cache — HBM scales with the horizon.

    ``kv_layout="paged"``: attention KV lives in a shared block pool
    (n, kv_blocks, kv_block, K, Dh) addressed through a per-slot block table
    (owned by the serving engine's ``runtime.kv_pager.BlockPager`` and passed
    to ``decode_step(block_table=)``) — HBM scales with kv_blocks, and
    ``max_len`` becomes a virtual horizon (it only sizes the table). The pairs
    plan's local-window stack instead gets a per-slot rolling ring cache
    (half, batch, ring_len, K, Dh); ``ring_len`` must be >= local_window +
    chunk - 1 for the chunk widths the caller will use. Recurrent (ssm/conv)
    state is O(1) per slot and is identical in both layouts.
    """
    cdt = canonical_dtype(cfg.compute_dtype)
    plan = layer_plan(cfg)
    assert kv_layout in ("dense", "paged"), kv_layout
    if kv_layout == "paged" and kv_blocks is None:
        kv_blocks = batch * (-(-max_len // kv_block))   # dense-equivalent pool

    def kv(n):
        if kv_layout == "paged":
            return {"k": jax.ShapeDtypeStruct(
                        (n, kv_blocks, kv_block, cfg.n_kv_heads, cfg.d_head), cdt),
                    "v": jax.ShapeDtypeStruct(
                        (n, kv_blocks, kv_block, cfg.n_kv_heads, cfg.d_head), cdt)}
        return {"k": jax.ShapeDtypeStruct(
                    (n, batch, max_len, cfg.n_kv_heads, cfg.d_head), cdt),
                "v": jax.ShapeDtypeStruct(
                    (n, batch, max_len, cfg.n_kv_heads, cfg.d_head), cdt)}

    def kv_ring(n):
        if kv_layout != "paged":
            return kv(n)
        w = ring_len if ring_len is not None else (cfg.local_window or max_len)
        return {"k": jax.ShapeDtypeStruct(
                    (n, batch, w, cfg.n_kv_heads, cfg.d_head), cdt),
                "v": jax.ShapeDtypeStruct(
                    (n, batch, w, cfg.n_kv_heads, cfg.d_head), cdt)}

    def ssm_states(n):
        sh = S.ssm_state_shapes(cfg.d_model, batch, expand=cfg.ssm_expand,
                                headdim=cfg.ssm_headdim, state=cfg.ssm_state,
                                d_conv=cfg.ssm_conv)
        return {"conv": jax.ShapeDtypeStruct((n,) + sh["conv"], cdt),
                "ssm": jax.ShapeDtypeStruct((n,) + sh["ssm"], jnp.float32)}

    if plan[0] == "uniform" and plan[1] == "attn":
        return {"layers": kv(cfg.n_layers)}
    if plan[0] == "uniform" and plan[1] == "ssm":
        return {"layers": ssm_states(cfg.n_layers)}
    if plan[0] == "pairs":
        # The local stack (a) only ever *reads* a window of the cache: under
        # the paged layout it keeps a rolling ring of the last ring_len
        # positions instead of full rows (see attention.attention_decode).
        return {"layers_a": kv_ring(plan[1]), "layers_b": kv(plan[1])}
    n_seg = len(layer_plan(cfg)[1])
    return {"layers": ssm_states(cfg.n_layers), "shared": kv(n_seg)}


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               kv_layout: str = "dense", kv_blocks: int | None = None,
               kv_block: int = 16, ring_len: int | None = None) -> dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, max_len, kv_layout=kv_layout,
                                    kv_blocks=kv_blocks, kv_block=kv_block,
                                    ring_len=ring_len))


def _mask_cache_rows(live, new, old):
    """Slot-mask invariant: rows where ``live`` is False keep their old cache.

    ``new``/``old`` are cache pytrees whose leaves carry the batch (slot) axis
    first; ``live`` is a (B,) bool mask. Without this, a decode step run on
    behalf of a subset of slots would scatter garbage KV/state into every other
    slot's row (the dummy token/position fed for non-target rows)."""
    if live is None:
        return new
    return jax.tree.map(
        lambda n, o: jnp.where(
            live.reshape((n.shape[0],) + (1,) * (n.ndim - 1)), n, o), new, old)


def _decode_scan(cfg, stack_params, x, cache, positions, spec, adapters, deltas,
                 *, kind: str, prefix: str, window, live=None,
                 block_table=None):
    ad = _subvars(adapters, prefix)
    de = _subvars(deltas, prefix)

    def body(x, xs):
        lp, c, ad_l, de_l = xs
        aux: dict = {}
        tap_ctx = (spec, ad_l, de_l, aux)
        if kind == "attn":
            x, k, v = B.attn_block_decode(cfg, lp, x, c["k"], c["v"], positions,
                                          window=window, tap_prefix=prefix,
                                          tap_ctx=tap_ctx, live=live,
                                          block_table=block_table)
            if block_table is not None:
                # paged pool leaves have no slot axis to revert: dead rows'
                # writes were already dropped at the scatter (OOB block ids).
                return x, {"k": k, "v": v}
            return x, _mask_cache_rows(live, {"k": k, "v": v}, c)
        x, conv, st = B.ssm_block_decode(cfg, lp, x, c["conv"], c["ssm"],
                                         tap_prefix=prefix, tap_ctx=tap_ctx)
        return x, _mask_cache_rows(live, {"conv": conv, "ssm": st}, c)

    return jax.lax.scan(body, x, (stack_params, cache, ad, de),
                        unroll=flags.scan_unroll())


def decode_step(cfg: ModelConfig, params: dict, batch: dict, cache: dict,
                spec: ColaSpec | None = None, cola_vars: dict | None = None,
                *, live: Array | None = None, block_table: Array | None = None):
    """One incremental step. batch: {"tokens": (B,c[,CB]) | "embeds": (B,c,d),
    "positions": (B,)} — c == 1 is the decode tick; c > 1 runs one chunk of a
    chunked prefill (the chunk attends to all earlier chunks through the
    cache, and recurrent state is carried across the boundary exactly).
    Returns (logits (B, c, V), new_cache).

    ``live``: optional (B,) bool mask; cache rows of non-live slots are left
    untouched (their logits are still computed but carry no meaning). Serving
    engines must pass this whenever a decode batch contains dead/padding slots.

    ``block_table``: (B, max_blocks) int32 — selects the paged KV layout (the
    cache must come from ``init_cache(kv_layout="paged")``): attention KV is
    addressed through the table into shared block pools, and the pairs plan's
    local stack through per-slot rolling ring caches.
    """
    adapters = (cola_vars or {}).get("adapters", {})
    deltas = (cola_vars or {}).get("deltas", {})
    positions = batch["positions"]
    x = embed_tokens(cfg, params, batch)
    plan = layer_plan(cfg)
    new_cache = dict(cache)
    if plan[0] == "uniform":
        x, nc = _decode_scan(cfg, params["layers"], x, cache["layers"],
                             positions, spec, adapters, deltas, kind=plan[1],
                             prefix="layers", window=None, live=live,
                             block_table=block_table)
        new_cache["layers"] = nc
    elif plan[0] == "pairs":
        def body(x, xs):
            lpa, lpb, ca, cb, ada, dea, adb, deb = xs
            aux: dict = {}
            x, ka, va = B.attn_block_decode(
                cfg, lpa, x, ca["k"], ca["v"], positions,
                window=cfg.local_window, tap_prefix="layers_a",
                tap_ctx=(spec, ada, dea, aux), live=live,
                ring=block_table is not None)
            x, kb, vb = B.attn_block_decode(
                cfg, lpb, x, cb["k"], cb["v"], positions, window=None,
                tap_prefix="layers_b", tap_ctx=(spec, adb, deb, aux), live=live,
                block_table=block_table)
            nb = ({"k": kb, "v": vb} if block_table is not None
                  else _mask_cache_rows(live, {"k": kb, "v": vb}, cb))
            return x, (_mask_cache_rows(live, {"k": ka, "v": va}, ca), nb)

        ad_a, de_a = _subvars(adapters, "layers_a"), _subvars(deltas, "layers_a")
        ad_b, de_b = _subvars(adapters, "layers_b"), _subvars(deltas, "layers_b")
        x, (nca, ncb) = jax.lax.scan(
            body, x,
            (params["layers_a"], params["layers_b"], cache["layers_a"],
             cache["layers_b"], ad_a, de_a, ad_b, de_b),
            unroll=flags.scan_unroll())
        new_cache["layers_a"], new_cache["layers_b"] = nca, ncb
    else:  # hybrid
        _, segs = layer_plan(cfg)
        sh_ad = _subvars(adapters, "shared")
        sh_de = _subvars(deltas, "shared")
        seg_caches = []
        shared_k, shared_v = [], []
        for i, (start, ln) in enumerate(segs):
            aux: dict = {}
            x, k, v = B.attn_block_decode(
                cfg, params["shared"], x, cache["shared"]["k"][i],
                cache["shared"]["v"][i], positions, window=None,
                tap_prefix="shared", tap_ctx=(spec, sh_ad, sh_de, aux),
                live=live, block_table=block_table)
            if block_table is not None:
                masked = {"k": k, "v": v}   # paged: dead-row writes dropped
            else:
                masked = _mask_cache_rows(
                    live, {"k": k, "v": v},
                    {"k": cache["shared"]["k"][i], "v": cache["shared"]["v"][i]})
            shared_k.append(masked["k"])
            shared_v.append(masked["v"])
            seg_params = _tree_slice(params["layers"], start, start + ln)
            seg_cache = _tree_slice(cache["layers"], start, start + ln)
            seg_ad = jax.tree.map(lambda a: a[start:start + ln],
                                  _subvars(adapters, "layers"))
            seg_de = jax.tree.map(lambda a: a[start:start + ln],
                                  _subvars(deltas, "layers"))
            x, nc = _decode_scan(cfg, seg_params, x, seg_cache, positions, spec,
                                 seg_ad, seg_de, kind="ssm", prefix="layers",
                                 window=None, live=live)
            seg_caches.append(nc)
        new_cache["layers"] = jax.tree.map(
            lambda *a: jnp.concatenate(a, axis=0), *seg_caches)
        new_cache["shared"] = {"k": jnp.stack(shared_k), "v": jnp.stack(shared_v)}

    x = L.rmsnorm(params["final_norm"], x, eps=cfg.norm_eps,
                  plus_one=cfg.norm_plus_one)
    logits = head_logits(cfg, params, x)
    return logits, new_cache


def prefill(cfg: ModelConfig, params: dict, batch: dict,
            spec: ColaSpec | None = None, cola_vars: dict | None = None,
            *, lengths: Array | None = None):
    """Full-sequence prefill; returns (logits, cache) with the cache holding the
    processed sequence (attn KV / ssm states).

    ``lengths``: optional (B,) per-row valid prompt lengths for right-padded
    batches; logits are then gathered at position ``lengths - 1`` per row
    instead of the last padded position. Causal masking makes every position
    < lengths[b] independent of the padding, so a padded batched prefill gives
    each row exactly its unpadded logits.
    """
    h, aux = hidden_states(cfg, params, batch, spec, cola_vars,
                           collect_kv=True, collect_state=True)
    if lengths is None:
        h_last = h[:, -1:]
    else:
        idx = jnp.clip(lengths.astype(jnp.int32) - 1, 0)[:, None, None]
        h_last = jnp.take_along_axis(
            h, jnp.broadcast_to(idx, (h.shape[0], 1, h.shape[-1])), axis=1)
    logits = head_logits(cfg, params, h_last)
    stacked = aux["stacked"]
    plan = layer_plan(cfg)
    if plan[0] == "uniform" and plan[1] == "attn":
        cache = {"layers": {"k": stacked["k"], "v": stacked["v"]}}
    elif plan[0] == "uniform" and plan[1] == "ssm":
        cache = {"layers": {"conv": stacked["conv"], "ssm": stacked["ssm"]}}
    elif plan[0] == "pairs":
        cache = {"layers_a": {"k": stacked["ka"], "v": stacked["va"]},
                 "layers_b": {"k": stacked["kb"], "v": stacked["vb"]}}
    else:
        cache = {"layers": {"conv": stacked["conv"], "ssm": stacked["ssm"]},
                 "shared": {"k": aux["shared_k"], "v": aux["shared_v"]}}
    return logits, cache


def scatter_prefill_cache(cache: dict, pre: dict, slot_ids: Array) -> dict:
    """Scatter a prefill cache (rows 0..J-1) into a serving slot cache.

    Every leaf carries (stack, batch, ...) leading axes. Attention KV leaves
    additionally carry a sequence axis (axis 2) of the prefill length S; they
    are written into slot positions [0, S). State leaves (ssm conv/state) have
    identical trailing shapes and are written whole. ``slot_ids`` (J,) maps
    prefill row j -> slot; out-of-range ids are dropped, which is how padding
    rows of a bucketed prefill batch are discarded.

    Positions >= the row's true prompt length receive pad-token KV. That is
    safe under the decode overwrite invariant: decode at position p writes the
    real KV at p before attending, and causal masking hides positions > p.
    It is NOT safe for recurrent (ssm/conv) state, which is a single final
    state folded over every input token including padding — rows for models
    with ``has_recurrent_state(cfg)`` must be prefilled at their exact length.
    """
    def upd(c, p):
        if p.ndim == c.ndim and c.ndim >= 3 and c.shape[2] != p.shape[2]:
            return c.at[:, slot_ids, :p.shape[2]].set(p, mode="drop")
        return c.at[:, slot_ids].set(p, mode="drop")

    return jax.tree.map(upd, cache, pre)
