"""Top-k routed Mixture-of-Experts (qwen3-moe, dbrx).

Two dispatch implementations, config-selectable (``moe_impl``):

- ``einsum`` — GShard-style dense one-hot dispatch/combine einsums. Robust SPMD
  lowering (expert axis sharded over `model` becomes all-to-all), but dispatch
  FLOPs scale with E*C and dominate at E=128. Kept as the literature baseline.
- ``sort``   — FLOP-optimal sorted/segmented dispatch: tokens are argsorted by
  expert id, gathered into an (E, C, d) buffer, batched-matmul'ed through the
  experts and scatter-added back. Gather/scatter are memory ops, so compiled
  FLOPs match 6*N_active*D. This is the beyond-paper perf path (§Perf).

Both share the same router semantics (softmax -> top-k -> renormalise) and the
switch-style load-balancing aux loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.utils import cdiv

Array = jax.Array


def moe_init(key: Array, d_model: int, n_experts: int, d_expert: int, dtype) -> dict:
    ks = jax.random.split(key, 4)

    def expert_weights(k, d_in, d_out):
        w = jax.random.normal(k, (n_experts, d_in, d_out), jnp.float32) * (d_in ** -0.5)
        return w.astype(dtype)

    return {
        "router": L.dense_init(ks[0], d_model, n_experts, dtype),
        "gate": expert_weights(ks[1], d_model, d_expert),
        "up": expert_weights(ks[2], d_model, d_expert),
        "down": expert_weights(ks[3], d_expert, d_model),
    }


def _route(params: dict, x: Array, top_k: int):
    """x: (..., d). Returns (weights (...,k), idx (...,k), aux_loss scalar)."""
    logits = (x.astype(jnp.float32) @ params["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, top_k)
    w = w / jnp.clip(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # switch-style load balance loss: E * sum_e mean(frac_tokens_e) * mean(prob_e)
    E = logits.shape[-1]
    me = jnp.mean(probs.reshape(-1, E), axis=0)
    one_hot = jax.nn.one_hot(idx.reshape(-1, top_k), E, dtype=jnp.float32)
    ce = jnp.mean(jnp.sum(one_hot, axis=1), axis=0) / top_k
    aux = E * jnp.sum(me * ce)
    return w, idx, aux


def _expert_ffn(params: dict, h: Array) -> Array:
    """h: (E, C, d) -> (E, C, d) through each expert's gated MLP."""
    g = jnp.einsum("ecd,edf->ecf", h, params["gate"].astype(h.dtype))
    u = jnp.einsum("ecd,edf->ecf", h, params["up"].astype(h.dtype))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                      params["down"].astype(h.dtype))


# ---------------------------------------------------------------------------
# einsum (GShard) dispatch
# ---------------------------------------------------------------------------

def moe_einsum(params: dict, x: Array, *, top_k: int,
               capacity_factor: float = 1.25, group: int = 512) -> tuple[Array, Array]:
    """x: (B, S, d) -> (B, S, d). Tokens are processed in dispatch groups of
    ``group`` tokens (GShard): the dispatch/combine tensors scale linearly with
    the group size, so smaller groups bound the transient memory."""
    Bz0, S0, d = x.shape
    T = Bz0 * S0
    G = group if T % group == 0 else S0
    x = x.reshape(T // G, G, d)
    Bz, S, _ = x.shape
    E = params["router"]["w"].shape[-1]
    C = max(top_k, cdiv(int(S * top_k * capacity_factor), E))
    w, idx, aux = _route(params, x, top_k)            # (B,S,k)

    # GShard position-in-expert accounting, sequential over the k choices.
    combine = jnp.zeros((Bz, S, E, C), jnp.float32)
    prev_counts = jnp.zeros((Bz, 1, E), jnp.float32)
    for j in range(top_k):
        mask_j = jax.nn.one_hot(idx[..., j], E, dtype=jnp.float32)   # (B,S,E)
        pos_j = jnp.cumsum(mask_j, axis=1) - mask_j + prev_counts     # (B,S,E)
        prev_counts = prev_counts + jnp.sum(mask_j, axis=1, keepdims=True)
        in_cap = (pos_j < C).astype(jnp.float32) * mask_j
        pos_oh = jax.nn.one_hot(pos_j.astype(jnp.int32), C, dtype=jnp.float32)
        combine = combine + (w[..., j, None, None] * in_cap[..., None] * pos_oh)
    dispatch = (combine > 0).astype(x.dtype)                         # (B,S,E,C)

    h = jnp.einsum("bsec,bsd->becd", dispatch, x)                    # (B,E,C,d)
    h = constrain(h, "batch", "model", None, None)
    y = jax.vmap(lambda hh: _expert_ffn(params, hh))(h)              # (B,E,C,d)
    y = constrain(y, "batch", "model", None, None)
    out = jnp.einsum("bsec,becd->bsd", combine.astype(x.dtype), y)
    return out.reshape(Bz0, S0, d), aux


# ---------------------------------------------------------------------------
# sort-based dispatch (FLOP-optimal)
# ---------------------------------------------------------------------------

def moe_sort(params: dict, x: Array, *, top_k: int,
             capacity_factor: float = 1.25) -> tuple[Array, Array]:
    Bz, S, d = x.shape
    E = params["router"]["w"].shape[-1]
    T = Bz * S
    C = max(top_k, cdiv(int(T * top_k * capacity_factor), E))
    xf = x.reshape(T, d)
    w, idx, aux = _route(params, xf, top_k)           # (T,k)

    flat_e = idx.reshape(-1)                          # (T*k,)
    flat_w = w.reshape(-1)
    sort_idx = jnp.argsort(flat_e, stable=True)       # (T*k,)
    sorted_e = flat_e[sort_idx]
    token_id = sort_idx // top_k                      # source token per slot

    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    offsets = jnp.cumsum(counts) - counts             # (E,)
    pos = jnp.arange(T * top_k, dtype=jnp.int32) - offsets[sorted_e]
    valid = pos < C
    slot = jnp.where(valid, sorted_e * C + pos, E * C)  # sentinel = drop

    buf = jnp.zeros((E * C, d), x.dtype).at[slot].set(xf[token_id], mode="drop")
    buf = constrain(buf.reshape(E, C, d), "model", None, None)
    y = constrain(_expert_ffn(params, buf), "model", None, None).reshape(E * C, d)
    contrib = y[jnp.clip(slot, 0, E * C - 1)] * jnp.where(
        valid, flat_w[sort_idx], 0.0)[:, None].astype(x.dtype)
    out = jnp.zeros((T, d), x.dtype).at[token_id].add(contrib)
    return out.reshape(Bz, S, d), aux


def moe_block(params: dict, x: Array, *, top_k: int, impl: str = "sort",
              capacity_factor: float = 1.25, group: int = 512) -> tuple[Array, Array]:
    if impl == "einsum":
        return moe_einsum(params, x, top_k=top_k, capacity_factor=capacity_factor,
                          group=group)
    if impl == "sort":
        return moe_sort(params, x, top_k=top_k, capacity_factor=capacity_factor)
    if impl == "dense":   # debug: run all experts densely (tiny configs only)
        w, idx, aux = _route(params, x, top_k)
        E = params["router"]["w"].shape[-1]
        hw = jnp.zeros(x.shape[:-1] + (E,), jnp.float32)
        for j in range(top_k):
            hw = hw + w[..., j, None] * jax.nn.one_hot(idx[..., j], E)
        g = jnp.einsum("bsd,edf->bsef", x, params["gate"].astype(x.dtype))
        u = jnp.einsum("bsd,edf->bsef", x, params["up"].astype(x.dtype))
        y = jnp.einsum("bsef,efd->bsed", jax.nn.silu(g) * u,
                       params["down"].astype(x.dtype))
        return jnp.einsum("bsed,bse->bsd", y, hw.astype(x.dtype)), aux
    raise ValueError(f"unknown moe impl {impl!r}")
