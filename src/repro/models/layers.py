"""Primitive layers (pure functions over param pytrees). No flax in this env —
everything is built from scratch on jnp.

Conventions
-----------
- Params are nested dicts of jnp arrays.
- Dense weights are stored as (d_in, d_out) in ``param_dtype``; compute happens in
  the activation dtype.
- Every Dense call may carry a *tap name* (see repro.core.taps) at which ColA can
  record hidden inputs / apply adapters / inject deltas. ``tap_ctx`` is the 4-tuple
  ``(spec, adapters, deltas, aux)`` threaded by the model; ``aux`` is a mutable dict
  the caller owns (function-local, so still functionally pure from jit's view).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import taps as taps_lib
from repro.distributed.sharding import constrain

Array = jax.Array


# ---------------------------------------------------------------------------
# initialisers
# ---------------------------------------------------------------------------

def dense_init(key: Array, d_in: int, d_out: int, dtype) -> dict:
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * (d_in ** -0.5)
    return {"w": w.astype(dtype)}


def embed_init(key: Array, vocab: int, d: int, dtype) -> dict:
    return {"emb": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)}


def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


# ---------------------------------------------------------------------------
# application
# ---------------------------------------------------------------------------

def dense(params: dict, x: Array, *, tap: str | None = None,
          tap_ctx: tuple | None = None) -> Array:
    """y = x @ W (+ ColA tap application)."""
    y = x @ params["w"].astype(x.dtype)
    if tap is not None and tap_ctx is not None:
        spec, adapters, deltas, aux = tap_ctx
        y, collected = taps_lib.apply_tap(spec, tap, x, y, adapters, deltas)
        aux.update(collected)
    return y


def embed(params: dict, ids: Array) -> Array:
    return params["emb"][ids]


def unembed(params: dict, x: Array) -> Array:
    """Tied unembedding: logits = x @ emb^T, computed in f32 for stability."""
    return x.astype(jnp.float32) @ params["emb"].astype(jnp.float32).T


def rmsnorm(params: dict, x: Array, *, eps: float = 1e-5,
            plus_one: bool = False) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    scale = params["scale"].astype(jnp.float32)
    if plus_one:   # gemma-style (1 + scale)
        scale = 1.0 + scale
    return (x * scale).astype(dt)


def softcap(x: Array, cap: float | None) -> Array:
    if cap is None or cap <= 0:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                     # (Dh/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]                   # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_init(key: Array, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, d_ff, dtype),
        "up": dense_init(k2, d_model, d_ff, dtype),
        "down": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp(params: dict, x: Array, *, act: str = "silu",
        tap_prefix: str | None = None, tap_ctx: tuple | None = None) -> Array:
    t = (lambda s: f"{tap_prefix}.{s}") if tap_prefix else (lambda s: None)
    g = dense(params["gate"], x, tap=t("gate"), tap_ctx=tap_ctx)
    u = dense(params["up"], x, tap=t("up"), tap_ctx=tap_ctx)
    if act == "silu":
        h = jax.nn.silu(g) * u
    elif act == "gelu":
        h = jax.nn.gelu(g, approximate=True) * u
    else:
        raise ValueError(act)
    if h.ndim == 3:
        h = constrain(h, "batch", None, "model")
    y = dense(params["down"], h, tap=t("down"), tap_ctx=tap_ctx)
    return constrain(y, "batch", None, None) if y.ndim == 3 else y
