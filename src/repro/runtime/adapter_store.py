"""Tiered adapter store: host-tier banks + LRU device residency.

ColA's FTaaS premise is *many* users per base model, but a device-resident
stacked bank (`stack_user_adapters`) caps the served population at whatever
fits in HBM — the user axis U is the bank's leading dimension. This module
decouples user count from device memory with two tiers:

- **Host tier** (the system of record): one numpy adapter pytree per user,
  stored f32 or int8 (codes + per-row scales, matching ``quantize_bank``),
  each carrying a version — the level that `publish_banks` / an
  `OffloadChannel`'s ``on_commit`` land validated fits in. Host RAM scales to
  millions of users; nothing here touches the accelerator.

- **Device tier**: a fixed-capacity resident bank of ``R`` rows (R << U) in
  the exact layout the ``multi_lora`` kernels consume — leaves
  ``(L?, R, d, r)`` — plus a user -> resident-row map. Decode batches index
  adapters by *resident row*, never by global user id, so kernel cost and
  adapter HBM are bounded by R.

Residency protocol (driven by ``ServeEngine``):

- ``acquire(user)`` pins a user before admission; a pinned user's row can
  never be evicted (their requests are live or queued into slots). ``acquire``
  refuses when the distinct pinned set would exceed R — admission then waits
  instead of deadlocking residency.
- ``ensure_resident(users)`` is prefetch-on-admission: hits touch the LRU
  clock; misses pick a free row (else evict the least-recently-used
  *unpinned* row) and land the host entry via per-leaf index updates
  (``bank.at[..., row].set``) — never a full-bank rebuild/restack.
- ``release(user)`` unpins on request completion (refcounted: a user may own
  several slots).

Layered on top: **task-similarity clustering** ("Collaborative and Efficient
Fine-tuning: Leveraging Task Similarity", PAPERS.md). ``build_clusters``
groups users whose adapter deltas are cosine-similar onto one *cluster*
entry — ``mode="shared"`` serves the representative member's adapters,
``mode="merged"`` the member average (``core.merge.merge_adapter_pytrees``).
Cluster members share a single resident row, shrinking the hot working set.
The mapping is copy-on-write: a member's own ``install`` splits them back
onto a private entry without perturbing the rest of the cluster.

Since every adapter contributes to a masked multi-LoRA accumulation as exact
float zeros for rows it does not own, serving through a resident bank of any
size R emits tokens *bit-identical* to the all-resident engine (asserted by
tests/test_adapter_store.py and benchmarks/serve_throughput.py).
"""
from __future__ import annotations

import time
from typing import Any, Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

UserKey = tuple  # ("user", uid) | ("cluster", cid)


# ---------------------------------------------------------------------------
# host-tier encoding
# ---------------------------------------------------------------------------

def _to_host(tree: dict) -> dict:
    return jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)


def _quantize_host(tree: dict) -> dict:
    """f32 per-user pytree -> int8 host entry (codes + per-row scales)."""
    from repro.kernels import multi_lora as ml
    out: dict[str, Any] = {}
    for tap, leaves in tree.items():
        entry = {}
        for name, leaf in leaves.items():
            q, s = ml.quant_rows(jnp.asarray(leaf, jnp.float32))
            entry[f"{name}_q"] = np.asarray(q)
            entry[f"{name}_scale"] = np.asarray(s)
        out[tap] = entry
    return out


def _dequantize_host(entry: dict) -> dict:
    """int8 host entry -> f32 pytree (for similarity vectors / merging)."""
    from repro.kernels import multi_lora as ml
    out: dict[str, Any] = {}
    for tap, leaves in entry.items():
        out[tap] = {}
        for name in sorted({n.rsplit("_", 1)[0] for n in leaves}):
            out[tap][name] = np.asarray(ml.dequant_rows(
                jnp.asarray(leaves[f"{name}_q"]),
                jnp.asarray(leaves[f"{name}_scale"])))
    return out


def _structure(adapters: dict) -> dict:
    return {tap: {n: tuple(np.shape(l)) for n, l in sorted(leaves.items())}
            for tap, leaves in adapters.items()}


def _cosine(a: np.ndarray, b: np.ndarray) -> float:
    na, nb = float(np.linalg.norm(a)), float(np.linalg.norm(b))
    if na == 0.0 and nb == 0.0:
        return 1.0          # two untrained (all-zero-delta) users are alike
    if na == 0.0 or nb == 0.0:
        return 0.0
    return float(np.dot(a, b) / (na * nb))


class AdapterStore:
    """Host-tier adapter bank with a fixed-R LRU device-resident cache."""

    def __init__(self, resident: int, *, store: str = "f32", telemetry=None):
        if resident < 1:
            raise ValueError(f"resident slot count must be >= 1, got {resident}")
        assert store in ("f32", "int8"), store
        self.resident = int(resident)
        self.store = store
        # observational only: fetch-latency histogram + per-user residency
        # breadcrumbs; `counters` stays the always-on authority
        self.tm = telemetry if telemetry else None
        # host tier: key -> numpy pytree; users route to a key (own or cluster)
        self._host: dict[UserKey, dict] = {}
        self._route: dict[int, UserKey] = {}
        self._versions: dict[int, int] = {}
        self._members: dict[int, set[int]] = {}   # cluster id -> member uids
        self._template: dict | None = None        # raw f32 structure signature
        # device tier
        self.bank: dict | None = None
        self._slot_key: list[UserKey | None] = [None] * self.resident
        self._key_slot: dict[UserKey, int] = {}
        self._last_used: list[int] = [0] * self.resident
        self._clock = 0
        self._pins: dict[int, int] = {}           # uid -> live/queued refcount
        self.counters = {
            "hits": 0, "misses": 0, "evictions": 0, "fetches": 0,
            "fetch_time": 0.0, "registered": 0, "installs": 0, "splits": 0,
        }

    @classmethod
    def from_users(cls, user_adapters: Sequence[dict], *, resident: int,
                   store: str = "f32", telemetry=None) -> "AdapterStore":
        st = cls(resident, store=store, telemetry=telemetry)
        for uid, adapters in enumerate(user_adapters):
            st.register(uid, adapters)
        return st

    # -- host tier ---------------------------------------------------------
    def _encode(self, adapters: dict) -> dict:
        return (_to_host(adapters) if self.store == "f32"
                else _quantize_host(adapters))

    def _f32_entry(self, key: UserKey) -> dict:
        entry = self._host[key]
        return entry if self.store == "f32" else _dequantize_host(entry)

    def register(self, user: int, adapters: dict, version: int = 0) -> None:
        """Add (or reset) one user's adapters in the host tier — the entry
        point for brand-new users arriving from training channels. Validates
        the pytree structure against the store template."""
        user = int(user)
        struct = _structure(adapters)
        if self._template is None:
            self._template = struct
            self._init_bank(adapters)
        elif struct != self._template:
            raise ValueError(
                f"user {user} adapter structure does not match the store "
                f"template: got {struct}, want {self._template}")
        key: UserKey = ("user", user)
        self._host[key] = self._encode(adapters)
        self._route[user] = key
        self._versions[user] = int(version)
        self.counters["registered"] += 1
        slot = self._key_slot.get(key)
        if slot is not None:     # re-registration of a resident user
            self._write_row(slot, self._host[key])

    def knows(self, user: int) -> bool:
        return int(user) in self._route

    def version(self, user: int) -> int:
        return self._versions[int(user)]

    def users(self) -> list[int]:
        return sorted(self._route)

    def cluster_of(self, user: int) -> int | None:
        key = self._route[int(user)]
        return key[1] if key[0] == "cluster" else None

    # -- device tier -------------------------------------------------------
    def _init_bank(self, adapters: dict) -> None:
        host0 = self._encode(adapters)
        bank: dict[str, Any] = {}
        for tap, leaves in host0.items():
            entry = {}
            for name, leaf in leaves.items():
                # user axis goes after any leading layer axis, mirroring
                # stack_user_adapters' (L, U, d, r) layout
                axis = 1 if leaf.ndim > 2 else 0
                shape = leaf.shape[:axis] + (self.resident,) + leaf.shape[axis:]
                entry[name] = jnp.zeros(shape, leaf.dtype)
            bank[tap] = entry
        self.bank = bank

    def _write_row(self, slot: int, entry: dict) -> None:
        """Land one host entry in resident row ``slot`` via per-leaf index
        updates — the bank is never rebuilt or restacked."""
        new_bank: dict[str, Any] = {}
        for tap, leaves in self.bank.items():
            new_entry = dict(leaves)
            for name, leaf in leaves.items():
                h = jnp.asarray(entry[tap][name])
                if h.ndim > 2:
                    new_entry[name] = leaf.at[:, slot].set(h)
                else:
                    new_entry[name] = leaf.at[slot].set(h)
            new_bank[tap] = new_entry
        self.bank = new_bank

    def _pinned_keys(self) -> set[UserKey]:
        return {self._route[u] for u in self._pins}

    def acquire(self, user: int) -> bool:
        """Pin a user ahead of admission. False when the user is unknown or
        pinning them would need more distinct resident rows than exist —
        admission must wait for live requests to complete."""
        user = int(user)
        if user not in self._route:
            return False
        if user in self._pins:
            self._pins[user] += 1
            return True
        pinned = self._pinned_keys()
        if self._route[user] not in pinned and len(pinned) >= self.resident:
            return False
        self._pins[user] = 1
        return True

    def release(self, user: int) -> None:
        user = int(user)
        n = self._pins.get(user, 0)
        if n <= 1:
            self._pins.pop(user, None)
        else:
            self._pins[user] = n - 1

    def pinned_count(self) -> int:
        return len(self._pins)

    def resident_index(self, user: int) -> int | None:
        return self._key_slot.get(self._route[int(user)])

    def ensure_resident(self, users: Iterable[int]) -> np.ndarray:
        """Prefetch-on-admission: make every user's adapters device-resident
        and return their resident row indices, evicting LRU unpinned rows as
        needed. Raises RuntimeError only if every row is pinned by some *other*
        user (the engine's ``acquire`` gate prevents this in normal flow)."""
        users = [int(u) for u in users]
        idx = np.zeros(len(users), np.int32)
        for j, user in enumerate(users):
            key = self._route[user]
            slot = self._key_slot.get(key)
            if slot is None:
                slot = self._fetch(key)
            else:
                self.counters["hits"] += 1
            self._clock += 1
            self._last_used[slot] = self._clock
            idx[j] = slot
        return idx

    def _fetch(self, key: UserKey) -> int:
        self.counters["misses"] += 1
        slot = next((s for s, k in enumerate(self._slot_key) if k is None),
                    None)
        evicted = None
        if slot is None:
            pinned = self._pinned_keys()
            victims = [(self._last_used[s], s)
                       for s, k in enumerate(self._slot_key)
                       if k not in pinned]
            if not victims:
                raise RuntimeError(
                    "adapter store: no evictable resident row (all "
                    f"{self.resident} rows pinned by live users)")
            _, slot = min(victims)
            evicted = self._slot_key[slot]
            del self._key_slot[evicted]
            self.counters["evictions"] += 1
        t0 = time.perf_counter()
        self._write_row(slot, self._host[key])
        dt = time.perf_counter() - t0
        self.counters["fetch_time"] += dt
        self.counters["fetches"] += 1
        if self.tm is not None:
            self.tm.registry.histogram("store.fetch_s").observe(dt)
            self.tm.record("user", key[1], "store_fetch", row=int(slot),
                           evicted=str(evicted) if evicted else None,
                           fetch_s=dt)
        self._slot_key[slot] = key
        self._key_slot[key] = slot
        return slot

    # -- adapter updates (train -> serve) ----------------------------------
    def install(self, user: int, adapters: dict, version: int) -> None:
        """Commit one user's new adapters into the host tier (and their
        resident row, if any). A clustered user is split off their cluster
        first (copy-on-write) — the cluster entry and every other member are
        untouched. Version/finiteness gating is the caller's job
        (``ServeEngine.install_adapters``); structure is validated here."""
        user = int(user)
        if user not in self._route:
            self.register(user, adapters, version=version)
            return
        struct = _structure(adapters)
        if struct != self._template:
            raise ValueError(
                f"user {user} install structure does not match the store "
                f"template: got {struct}, want {self._template}")
        if self._route[user][0] == "cluster":
            self.split(user)
        key = self._route[user]
        self._host[key] = self._encode(adapters)
        self._versions[user] = int(version)
        self.counters["installs"] += 1
        slot = self._key_slot.get(key)
        if slot is not None:
            self._write_row(slot, self._host[key])

    def split(self, user: int) -> None:
        """Copy-on-write split: route a cluster member back onto their own
        host entry. The cluster row (and its other members' serving) is not
        perturbed; the user's residency re-resolves on their next admission
        or install."""
        user = int(user)
        key = self._route[user]
        if key[0] != "cluster":
            return
        self._members[key[1]].discard(user)
        own: UserKey = ("user", user)
        if own not in self._host:
            # the member's pre-clustering entry was kept as their COW base;
            # a user first registered *into* a cluster copies the cluster bank
            self._host[own] = {tap: dict(leaves)
                               for tap, leaves in self._host[key].items()}
        self._route[user] = own
        self.counters["splits"] += 1

    # -- task-similarity clustering ----------------------------------------
    def _flat_vector(self, user: int) -> np.ndarray:
        entry = self._f32_entry(("user", int(user)))
        parts = [np.asarray(entry[tap][name], np.float64).ravel()
                 for tap in sorted(entry)
                 for name in sorted(entry[tap])]
        return np.concatenate(parts)

    def build_clusters(self, threshold: float, mode: str = "shared"
                       ) -> dict[int, list[int]]:
        """Greedy cosine clustering of user adapter deltas: each user joins
        the first cluster whose representative has similarity >= threshold.
        Multi-member clusters get one shared host entry (``shared``: the
        representative's adapters; ``merged``: the member mean via
        ``merge_adapter_pytrees``) and thus one resident row. Returns
        {cluster id: members} for multi-member clusters."""
        assert mode in ("shared", "merged"), mode
        if self._pins:
            raise RuntimeError("cannot re-cluster while users are pinned "
                               "(live or queued requests hold rows)")
        users = sorted(u for u, k in self._route.items() if k[0] == "user")
        vectors = {u: self._flat_vector(u) for u in users}
        groups: list[list[int]] = []
        reps: list[np.ndarray] = []
        for u in users:
            for ci, rep in enumerate(reps):
                if _cosine(vectors[u], rep) >= threshold:
                    groups[ci].append(u)
                    break
            else:
                groups.append([u])
                reps.append(vectors[u])
        next_cid = max(self._members, default=-1) + 1
        out: dict[int, list[int]] = {}
        for members in groups:
            if len(members) < 2:
                continue
            cid, next_cid = next_cid, next_cid + 1
            ckey: UserKey = ("cluster", cid)
            if mode == "shared":
                entry = {tap: dict(leaves) for tap, leaves
                         in self._host[("user", members[0])].items()}
            else:
                from repro.core.merge import merge_adapter_pytrees
                entry = self._encode(merge_adapter_pytrees(
                    [self._f32_entry(("user", u)) for u in members]))
            self._host[ckey] = entry
            self._members[cid] = set(members)
            for u in members:
                self._route[u] = ckey
            out[cid] = list(members)
        return out

    # -- metrics -----------------------------------------------------------
    def resident_bytes(self) -> int:
        if self.bank is None:
            return 0
        return int(sum(l.nbytes for l in jax.tree.leaves(self.bank)))

    def host_bytes(self) -> int:
        return int(sum(l.nbytes for entry in self._host.values()
                       for l in jax.tree.leaves(entry)))

    def metrics(self) -> dict:
        out = dict(self.counters)
        touches = out["hits"] + out["misses"]
        out["hit_rate"] = out["hits"] / touches if touches else 0.0
        out["pinned"] = len(self._pins)
        out["resident_users"] = sum(k is not None for k in self._slot_key)
        out["resident_bytes"] = self.resident_bytes()
        out["host_users"] = len(self._route)
        out["host_bytes"] = self.host_bytes()
        out["clusters"] = sum(1 for m in self._members.values() if len(m) > 1)
        return out

    def reset_counters(self) -> None:
        for k, v in self.counters.items():
            self.counters[k] = 0 if isinstance(v, int) else 0.0
