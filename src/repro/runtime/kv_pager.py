"""Paged KV cache block pager: host-side pool accounting for the serving
engine's ``kv_layout="paged"`` cache.

The dense slot cache allocates ``slots x max_len`` KV positions per layer up
front, so HBM scales with the *horizon*, not with the tokens actually held.
The paged layout instead stores KV in a fixed pool of ``n_blocks`` blocks of
``block_size`` positions (one pool row per block, shared by every slot), and
each slot owns an ordered list of blocks covering positions
``[0, len(owned) * block_size)``. The device sees only:

- the per-layer pools ``(L, n_blocks, block_size, K, Dh)`` (model cache leaves;
  every layer stack indexes the *same* block ids along its pool axis), and
- one ``(slots, max_blocks)`` int32 **block table** mapping
  ``(slot, position // block_size) -> pool block id``, shipped to the jitted
  decode/chunk step and read there (or scalar-prefetched into SMEM by the
  fused Pallas kernel).

The pager itself is pure host bookkeeping — numpy lists and counters, no jax —
so allocation never sits on the decode hot path: the engine calls ``ensure``
before launching a tick and only the (tiny) table array crosses to the device.

Invariants (guarded here and by tests/test_paged_kv.py, tests/test_faults.py):

- **Reservation-backed admission.** ``reserve(slot, n)`` claims capacity for a
  request's worst case (prompt + chunk padding + decode horizon) at admission;
  it fails — and the engine keeps the request queued — rather than letting a
  mid-flight ``ensure`` run the pool dry. Allocation draws down the slot's
  reservation, so concurrent slots can never over-commit the pool.
- **Refcounted frees.** Every block carries a refcount (1 while owned; the
  hook for future prefix sharing). ``release`` decrements and returns blocks
  to the free list at zero; a double free or a foreign free raises instead of
  corrupting the free list.
- **No leaks.** ``blocks_in_use == sum(owned)`` always; after every slot is
  released the pool is whole again (``assert_empty``).
- **Live-mask interaction.** Unallocated table entries point at block 0 (a
  valid pool row): reads are masked by position (causality never touches
  positions beyond a slot's allocated prefix) and dead rows' *writes* are
  dropped at the index level (the engine passes block id ``n_blocks`` for
  non-live rows, written with ``mode="drop"``) — the paged analogue of the
  dense layout's ``_mask_cache_rows`` revert.
"""
from __future__ import annotations

import numpy as np


class PagerError(RuntimeError):
    """Pool accounting violation (double free, foreign free, leak)."""


class BlockPager:
    """Host-side block pool accounting + the device-shippable block table."""

    def __init__(self, n_blocks: int, block_size: int, slots: int,
                 max_len: int, telemetry=None):
        if n_blocks < 1 or block_size < 1:
            raise ValueError(f"need n_blocks >= 1 and block_size >= 1, got "
                             f"{n_blocks}, {block_size}")
        # observational only (flight-recorder breadcrumbs + postmortems on
        # accounting violations); the pager never blocks on it
        self.tm = telemetry if telemetry else None
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.slots = slots
        self.max_blocks = -(-max_len // block_size)   # table width (per slot)
        self._free: list[int] = list(range(n_blocks - 1, -1, -1))
        self._owned: list[list[int]] = [[] for _ in range(slots)]
        self._reserved = np.zeros(slots, np.int64)
        self._refcount = np.zeros(n_blocks, np.int32)
        # unallocated entries point at block 0: always a valid pool row, and
        # never *read* thanks to position masking (see module docstring).
        self.table = np.zeros((slots, self.max_blocks), np.int32)
        self.stats = {"allocs": 0, "frees": 0, "in_use": 0, "peak_in_use": 0,
                      "reserve_failures": 0}

    # -- telemetry ---------------------------------------------------------
    def _record(self, slot: int, kind: str, **fields) -> None:
        if self.tm is not None:
            self.tm.record("slot", slot, kind, **fields)

    def _raise(self, slot, msg: str) -> None:
        """Freeze the offending slot's flight-recorder ring into a postmortem
        before raising — a PagerError is a terminal accounting violation and
        the events leading up to it are the evidence."""
        if self.tm is not None:
            self.tm.record("slot", slot, "pager_error", message=msg)
            self.tm.dump("slot", slot, f"PagerError: {msg}")
        raise PagerError(msg)

    # -- capacity ----------------------------------------------------------
    def blocks_for(self, n_positions: int) -> int:
        """Blocks needed to hold positions [0, n_positions)."""
        return -(-max(n_positions, 0) // self.block_size)

    def free_unreserved(self) -> int:
        return len(self._free) - int(self._reserved.sum())

    def capacity(self, slot: int) -> int:
        """Positions currently backed by allocated blocks for ``slot``."""
        return len(self._owned[slot]) * self.block_size

    # -- reservation -------------------------------------------------------
    def reserve(self, slot: int, n_positions: int) -> bool:
        """Claim capacity for ``n_positions`` total positions on ``slot``
        (on top of blocks it already owns). Returns False — claiming nothing —
        when the pool cannot guarantee it, so admission can wait FIFO."""
        need = self.blocks_for(n_positions) - len(self._owned[slot])
        need = max(need - int(self._reserved[slot]), 0)
        if need > self.free_unreserved():
            self.stats["reserve_failures"] += 1
            self._record(slot, "kv_reserve_fail", need=need,
                         free_unreserved=self.free_unreserved())
            return False
        self._reserved[slot] += need
        self._record(slot, "kv_reserve", blocks=need)
        return True

    # -- alloc / free ------------------------------------------------------
    def ensure(self, slot: int, upto_pos: int) -> bool:
        """Allocate blocks so ``slot`` can hold positions [0, upto_pos].
        Draws down the slot's reservation first; allocation beyond it only
        succeeds while unreserved blocks remain. Returns whether the slot now
        has the capacity."""
        owned = self._owned[slot]
        while self.capacity(slot) <= upto_pos:
            if not self._free:
                return False
            if self._reserved[slot] > 0:
                self._reserved[slot] -= 1
            elif self.free_unreserved() <= 0:
                return False   # every free block is promised to another slot
            blk = self._free.pop()
            self._refcount[blk] += 1
            self.table[slot, len(owned)] = blk
            owned.append(blk)
            self.stats["allocs"] += 1
            self.stats["in_use"] += 1
            self.stats["peak_in_use"] = max(self.stats["peak_in_use"],
                                            self.stats["in_use"])
        return True

    def release(self, slot: int) -> None:
        """Retire a slot: unref every owned block (freeing at refcount zero)
        and drop any unused reservation. Double/foreign frees raise."""
        for blk in self._owned[slot]:
            if self._refcount[blk] <= 0:
                self._raise(slot, f"double free of block {blk} (slot {slot})")
            self._refcount[blk] -= 1
            if self._refcount[blk] == 0:
                self._free.append(blk)
                self.stats["frees"] += 1
                self.stats["in_use"] -= 1
        self._record(slot, "kv_release", blocks=len(self._owned[slot]))
        self._owned[slot] = []
        self._reserved[slot] = 0
        self.table[slot, :] = 0

    # -- introspection -----------------------------------------------------
    def blocks_in_use(self) -> int:
        return self.stats["in_use"]

    def owned(self, slot: int) -> tuple[int, ...]:
        return tuple(self._owned[slot])

    def assert_empty(self) -> None:
        """Raise unless the pool is whole (no leaked or still-owned blocks)."""
        owned = sum(len(o) for o in self._owned)
        if owned or self.stats["in_use"] != 0:
            self._raise("pool", f"leaked blocks: {owned} still owned, "
                        f"in_use={self.stats['in_use']}")
        if len(self._free) != self.n_blocks:
            self._raise("pool", f"free list holds {len(self._free)} of "
                        f"{self.n_blocks} blocks")
        if int(self._refcount.sum()) != 0:
            self._raise("pool", "nonzero refcounts on an empty pool")
