"""Batched serving engine with continuous batching and multi-user adapters —
the inference half of FTaaS: one base model, K users' adapters applied
per-request inside one decode batch (multi-LoRA; the ``multi_lora`` Pallas
kernel's job on TPU).

Design: fixed decode slots. Each slot holds (request id, user id, position,
done). Admission drains up to ``admit_batch`` waiting requests per tick into
free slots and prefills them **as one padded batch** through
``model_lib.prefill`` (per-row user-id adapter routing via the multi_lora
kernel), scattering the resulting KV/state into the slot cache
(``model_lib.scatter_prefill_cache``). Every engine tick then decodes one
token for all live slots.

Lifecycle:  submit -> admit (batched prefill into slots) -> decode ticks ->
complete (slot freed, stats recorded).

Slot-mask invariant: every decode step carries a (slots,) ``live`` mask and
``model_lib.decode_step`` reverts cache writes of non-live rows, so neither
admission nor decoding on behalf of a subset of slots can touch another live
slot's KV (the old single-row prefill clobbered position 0 of every other
slot — fixed here and guarded by tests/test_serving.py).

The token-by-token single-row path is kept as a reference implementation
(``prefill_mode="reference"``) for the batched==reference equivalence tests.

Chunked prefill (``prefill_chunk=C``): instead of one monolithic prefill
forward at admission, each prompt is split into C-token chunks and exactly one
chunk round runs per engine tick, interleaved with the live decode batch
(Sarathi-style). A long prompt then stalls decode by at most one chunk of
model work per tick instead of a full prompt forward. Chunks run through
``model_lib.decode_step`` with ``c > 1`` tokens per row: non-recurrent rows go
as one width-C padded group (per-row ``lens`` gathers each row's last real
logit), recurrent (ssm/hybrid) rows are grouped by exact chunk width so no
padding ever touches conv/ssd state, which is carried across chunk boundaries
exactly. The first generated token is emitted straight from the final chunk's
logits (and, unchunked, from the prefill logits via ``prefill(lengths=)``) —
no decode tick is spent re-deriving it.

Paged KV (``kv_layout="paged"``, requires chunked prefill): the dense
(slots, max_len) slot cache is replaced by a shared block pool plus a per-slot
block table (``runtime.kv_pager.BlockPager``). Blocks are allocated on demand
as positions are written and freed at retirement, so KV HBM scales with
*tokens held*, not slots x horizon — ``max_len`` becomes a virtual horizon
that only sizes the block table. Admission reserves each request's worst-case
block count up front, so a mid-flight allocation can never run the pool dry.
gemma2-style local-window stacks keep a per-slot ring cache of
``local_window + C - 1`` positions instead of pool blocks.

Adapter banks come in two flavours: the dense device-resident stack
(``stack_user_adapters``; U bounded by HBM) and, with ``resident_slots=R``,
the tiered ``AdapterStore`` (runtime/adapter_store.py): every user lives in a
host-tier numpy bank and only an R-row LRU cache is device-resident. Admission
pins users and prefetches their residency; decode/prefill then route by
*resident row index* (``res_idx``), never by global user id, so adapter HBM
and kernel cost are bounded by R while tokens stay bit-identical to the
all-resident engine.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import gl
from repro.core import taps as taps_lib
from repro.models import model as model_lib
from repro.runtime.adapter_store import AdapterStore
from repro.runtime.kv_pager import BlockPager
from repro.telemetry import NULL_CONTEXT, annotate
from repro.telemetry.metrics import NULL_METRIC, percentiles

Array = jax.Array


@dataclasses.dataclass
class Request:
    rid: int
    user: int
    prompt: np.ndarray          # (P,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    # "queued" -> "done" | "rejected:<reason>" (terminal without a slot)
    status: str = "queued"
    # lifecycle timestamps (perf_counter seconds), filled by the engine
    t_submit: float | None = None
    t_admit: float | None = None
    t_first: float | None = None
    t_done: float | None = None

    @property
    def ttft(self) -> float | None:
        """Time to first token, from submission."""
        if self.t_submit is None or self.t_first is None:
            return None
        return self.t_first - self.t_submit

    @property
    def latency(self) -> float | None:
        if self.t_submit is None or self.t_done is None:
            return None
        return self.t_done - self.t_submit


def stack_user_adapters(adapter_list: list[dict]) -> dict:
    """K per-user adapter pytrees {tap: {"A": (L?,d,r), "B": ...}} -> multi
    bank {tap: {"A": (L?,U,d,r), ...}} (user axis after any layer axis)."""
    if not adapter_list:
        raise ValueError("stack_user_adapters: need at least one per-user "
                         "adapter pytree, got an empty list")

    def _struct(a: dict) -> dict:
        return {tap: {n: tuple(np.shape(l)) for n, l in sorted(leaves.items())}
                for tap, leaves in a.items()}

    want = _struct(adapter_list[0])
    for u, a in enumerate(adapter_list[1:], start=1):
        got = _struct(a)
        if got != want:
            raise ValueError(
                f"stack_user_adapters: user {u} adapter structure {got} does "
                f"not match user 0 structure {want} (all users must share the "
                "same tap set and leaf shapes)")
    out: dict[str, Any] = {}
    for tap in adapter_list[0]:
        leaves = {}
        for name in adapter_list[0][tap]:
            stacked = jnp.stack([a[tap][name] for a in adapter_list], axis=0)
            if adapter_list[0][tap][name].ndim > 2:   # (L, d, r) -> (L, U, d, r)
                stacked = jnp.moveaxis(stacked, 0, 1)
            leaves[name] = stacked
        out[tap] = leaves
    return out


def quantize_bank(bank: dict) -> dict:
    """f32 multi-user bank -> int8-stored bank: every leaf ``name`` becomes
    ``name_q`` (int8) + ``name_scale`` (per-row f32). The serve path then
    dequantises on kernel tile load (kernels/multi_lora.multi_lora_q8) instead
    of ever holding a f32 copy of the bank — 4x less adapter HBM per user."""
    from repro.kernels import multi_lora as ml
    out: dict[str, Any] = {}
    for tap, leaves in bank.items():
        entry = {}
        for name, leaf in leaves.items():
            q, s = ml.quant_rows(leaf)
            entry[f"{name}_q"] = q
            entry[f"{name}_scale"] = s
        out[tap] = entry
    return out


def publish_banks(engine: "ServeEngine", channels) -> int:
    """Install every `OffloadChannel`'s bank that carries a validated version
    bump into the serving engine (the train -> serve hot-swap path). Channels
    that are quarantined or stale simply keep serving their last-good bank.

    With a tiered adapter store, a channel whose user the engine has never
    seen is *registered* into the host tier (new users join serving without a
    bank restack); without one, out-of-range users are skipped and counted in
    ``stats["bank_unknown_user"]`` instead of crashing the publish sweep.
    Returns the number of banks installed (registrations included)."""
    installed = 0
    for ch in channels:
        if engine.store is not None:
            if not engine.store.knows(ch.user):
                if engine.install_adapters(ch.user, ch.adapters, ch.version):
                    installed += 1
                continue
            if ch.version > engine.store.version(ch.user):
                if engine.install_adapters(ch.user, ch.adapters, ch.version):
                    installed += 1
            continue
        if engine.bank_versions is None:
            break
        if not 0 <= ch.user < engine.n_users:
            engine.stats["bank_unknown_user"] += 1
            continue
        if ch.version > int(engine.bank_versions[ch.user]):
            if engine.install_adapters(ch.user, ch.adapters, ch.version):
                installed += 1
    return installed


def _bucket(n: int, floor: int = 8) -> int:
    """Round up to a power of two (>= floor) to bound jit recompilations of the
    prefill step across varying admitted-batch shapes."""
    b = floor
    while b < n:
        b *= 2
    return b


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: dict, *, slots: int = 8,
                 max_len: int = 512, user_adapters: list[dict] | None = None,
                 taps: str = "qv", scale: float = 1.0,
                 prefill_mode: str = "batched", admit_batch: int | None = None,
                 bank_store: str = "f32", decode_burst: int = 1,
                 resident_slots: int | None = None,
                 cluster_threshold: float | None = None,
                 cluster_mode: str = "shared",
                 prefill_chunk: int | None = None,
                 kv_layout: str = "dense", kv_block: int = 16,
                 kv_blocks: int | None = None,
                 max_prompt: int | None = None,
                 telemetry=None):
        assert prefill_mode in ("batched", "reference"), prefill_mode
        assert bank_store in ("f32", "int8"), bank_store
        assert kv_layout in ("dense", "paged"), kv_layout
        if prefill_chunk is not None:
            assert prefill_chunk >= 1, prefill_chunk
            assert prefill_mode == "batched", (
                "chunked prefill requires prefill_mode='batched' (the "
                "reference mode exists to oracle the unchunked path)")
        if kv_layout == "paged":
            assert prefill_chunk is not None, (
                "kv_layout='paged' requires prefill_chunk: the monolithic "
                "prefill scatters a dense cache (scatter_prefill_cache), "
                "only the chunked path writes through the block table")
        # Telemetry is strictly observational: it only reads host-side values
        # after dispatches complete, so generated tokens are bit-identical
        # telemetry-on vs. off (guarded by tests/test_telemetry.py). The
        # disabled path is `self.tm is None` checks plus NULL_METRIC no-ops.
        self.tm = telemetry if telemetry else None
        _reg = self.tm.registry if self.tm else None
        _hist = (_reg.histogram if _reg is not None
                 else (lambda name: NULL_METRIC))
        self._h_ttft = _hist("serve.ttft_s")
        self._h_latency = _hist("serve.latency_s")
        self._h_decode_tick = _hist("serve.decode_tick_s")
        self._h_prefill_chunk = _hist("serve.prefill_chunk_s")
        self._h_prefill_call = _hist("serve.prefill_call_s")
        if self.tm:
            self.tm.name_thread(0, "serve")
        # always-on bounded duration samples so throughput() reports tail
        # percentiles (satellite 1) even without a Telemetry attached
        self._decode_tick_s: collections.deque = collections.deque(maxlen=4096)
        self._prefill_s: collections.deque = collections.deque(maxlen=4096)
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.prefill_mode = prefill_mode
        self.prefill_chunk = prefill_chunk
        self.kv_layout = kv_layout
        self.kv_block = kv_block
        # a prompt occupies [0, P) and one decode position must remain below
        # the horizon, so max_prompt can never exceed max_len - 1
        self.max_prompt = (int(max_prompt) if max_prompt is not None
                           else max_len - 1)
        assert 1 <= self.max_prompt <= max_len - 1, self.max_prompt
        self.admit_batch = admit_batch if admit_batch is not None else slots
        self.bank_store = bank_store
        # Burst decoding: fuse up to ``decode_burst`` decode ticks into one
        # jitted lax.scan, amortising per-dispatch overhead. Bursts only run
        # when no live slot could complete mid-burst, so emitted tokens are
        # bit-identical to decode_burst=1 (guarded by tests/test_serving.py).
        self.decode_burst = max(1, int(decode_burst))
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.active: list[Request | None] = [None] * slots
        self.positions = np.zeros(slots, np.int32)
        self.users = np.zeros(slots, np.int32)
        self.pager: BlockPager | None = None
        ring_len = None
        if kv_layout == "paged":
            n_blocks = (kv_blocks if kv_blocks is not None
                        else slots * (-(-max_len // kv_block)))
            self.pager = BlockPager(n_blocks, kv_block, slots, max_len,
                                    telemetry=self.tm)
            kv_blocks = n_blocks
            if model_lib.layer_plan(cfg)[0] == "pairs":
                # local-window ring: must hold the window plus a full chunk's
                # in-flight writes (see models/attention.attention_decode)
                ring_len = (cfg.local_window or max_len) + prefill_chunk - 1
        self.cache = model_lib.init_cache(cfg, slots, max_len,
                                          kv_layout=kv_layout,
                                          kv_blocks=kv_blocks,
                                          kv_block=kv_block, ring_len=ring_len)
        self.spec = None
        self.bank = None
        self.store: AdapterStore | None = None
        self.res_idx = np.zeros(slots, np.int32)   # per-slot resident row
        self.n_users = 0
        self.bank_versions: np.ndarray | None = None
        if user_adapters:
            tap_names = gl.select_taps(cfg, taps)
            self.spec = taps_lib.make_spec(family="multi_lowrank",
                                           taps=tap_names, scale=scale)
            self.n_users = len(user_adapters)
            if resident_slots is not None:
                # tiered store: host tier holds every user, the device bank is
                # a fixed-R LRU cache — user count decouples from HBM.
                self.store = AdapterStore.from_users(
                    user_adapters, resident=resident_slots, store=bank_store,
                    telemetry=self.tm)
                if cluster_threshold is not None:
                    self.store.build_clusters(cluster_threshold,
                                              mode=cluster_mode)
            else:
                self.bank = stack_user_adapters(user_adapters)
                if bank_store == "int8":
                    self.bank = quantize_bank(self.bank)
                self.bank_versions = np.zeros(self.n_users, np.int64)
        elif resident_slots is not None:
            raise ValueError("resident_slots requires user_adapters (the "
                             "store template comes from the first user)")
        self._recurrent = model_lib.has_recurrent_state(cfg)
        self._decode = jax.jit(self._decode_fn)
        self._decode_n = jax.jit(self._decode_burst_fn, static_argnames=("n",))
        self._prefill = jax.jit(self._prefill_fn)
        self._chunk = jax.jit(self._chunk_fn)
        self.stats = {"ticks": 0, "tokens": 0, "decode_tokens": 0,
                      "completed": 0, "admitted": 0,
                      "prefill_calls": 0, "prefill_tokens": 0,
                      "prefill_chunks": 0, "chunk_rounds": 0,
                      "decode_time": 0.0, "prefill_time": 0.0,
                      "rejected": 0, "bank_installs": 0, "bank_rejected": 0,
                      "bank_unknown_user": 0,
                      "kv_blocks_in_use": 0, "kv_blocks_peak": 0,
                      "kv_allocs": 0, "kv_frees": 0, "kv_reserve_failures": 0,
                      "store_hits": 0, "store_misses": 0, "store_evictions": 0,
                      "store_hit_rate": 0.0, "store_pinned": 0,
                      "store_resident_bytes": 0, "store_fetch_time": 0.0}

    # -- telemetry ---------------------------------------------------------
    def _span(self, name: str, **args):
        """A serve-lane trace span, or the shared null context when tracing
        is off — cheap enough to leave inline in the tick path."""
        if self.tm is None:
            return NULL_CONTEXT
        return self.tm.span(name, cat="serve", tid=0, **args)

    def _record(self, scope: str, key, kind: str, **fields) -> None:
        if self.tm is not None:
            self.tm.record(scope, key, kind, **fields)

    def telemetry_snapshot(self) -> dict:
        """Sync the legacy stat dicts, absorb them into the metric registry
        under ``serve.*`` / ``store.*`` / ``pager.*`` and return the registry
        snapshot. Empty dict when telemetry is disabled — ``engine.stats``
        stays the always-on authority."""
        if self.tm is None:
            return {}
        self._sync_store_stats()
        self._sync_pager_stats()
        reg = self.tm.registry
        # store_*/kv_* keys are mirrors of the store/pager dicts; absorb the
        # originals under their own namespaces instead of duplicating them
        reg.absorb("serve", {k: v for k, v in self.stats.items()
                             if not k.startswith(("store_", "kv_"))})
        if self.store is not None:
            reg.absorb("store", self.store.metrics())
        if self.pager is not None:
            reg.absorb("pager", self.pager.stats)
        return reg.snapshot()

    # -- jitted core -----------------------------------------------------
    # The bank is a jit *argument*, never a closure: a closed-over bank would
    # be baked into the compiled decode as a trace-time constant, silently
    # ignoring every later `install_adapters` hot-swap (shapes are stable
    # across swaps, so passing it as an input costs no recompilation).
    def _cola_vars(self, bank, users: Array) -> dict | None:
        if bank is None:
            return None
        vars_ = {}
        for tap, leaves in bank.items():
            entry = dict(leaves)
            a = leaves.get("A", leaves.get("A_q"))   # int8 banks carry A_q
            if a.ndim == 4:   # stacked (L, U, d, r): idx must carry the layer
                entry["idx"] = jnp.broadcast_to(users, (a.shape[0],) + users.shape)
            else:
                entry["idx"] = users
            vars_[tap] = entry
        return {"adapters": vars_}

    def _decode_fn(self, params, bank, cache, table, tokens, positions, users,
                   live):
        batch = {"tokens": tokens, "positions": positions}
        logits, cache = model_lib.decode_step(
            self.cfg, params, batch, cache, self.spec,
            self._cola_vars(bank, users), live=live, block_table=table)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache

    def _decode_burst_fn(self, params, bank, cache, table, tokens, positions,
                         users, live, *, n: int):
        """``n`` chained decode ticks in one jitted lax.scan: each step feeds
        its argmax token back as the next step's input and advances live rows'
        positions. Returns the (n, slots) token trace plus the final cache.
        Dead rows keep their input token and position, matching what the
        host-side loop would have passed on every individual tick."""
        def body(carry, _):
            toks, pos, cache = carry
            batch = {"tokens": toks, "positions": pos}
            logits, cache = model_lib.decode_step(
                self.cfg, params, batch, cache, self.spec,
                self._cola_vars(bank, users), live=live, block_table=table)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            toks = jnp.where(live, nxt, toks[:, 0])[:, None]
            pos = pos + live.astype(pos.dtype)
            return (toks, pos, cache), nxt
        (_, _, cache), trace = jax.lax.scan(
            body, (tokens, positions, cache), None, length=n)
        return trace, cache

    def _prefill_fn(self, params, bank, cache, tokens, users, slot_ids,
                    lengths):
        """Run a padded (J, P) prompt batch through full-sequence prefill,
        scatter each row's KV/state into its slot and return each row's first
        generated token (argmax of the logits at its true last prompt
        position, gathered by ``lengths``). Padding rows carry an out-of-range
        slot id and are dropped by the scatter."""
        logits, pre = model_lib.prefill(self.cfg, params, {"tokens": tokens},
                                        self.spec, self._cola_vars(bank, users),
                                        lengths=lengths)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, model_lib.scatter_prefill_cache(cache, pre, slot_ids)

    def _chunk_fn(self, params, bank, cache, table, tokens, positions, users,
                  live, lens):
        """One prefill chunk round: a (slots, width) token batch through the
        multi-token decode step. ``lens[i]`` is row i's real chunk length
        (<= width; the rest is padding whose cache writes are masked/dropped);
        the returned token is each row's argmax at its last real position —
        meaningful only for rows whose prompt just completed."""
        batch = {"tokens": tokens, "positions": positions}
        logits, cache = model_lib.decode_step(
            self.cfg, params, batch, cache, self.spec,
            self._cola_vars(bank, users), live=live, block_table=table)
        rows = jnp.arange(tokens.shape[0])
        last = logits[rows, jnp.clip(lens - 1, 0)]
        return jnp.argmax(last, axis=-1).astype(jnp.int32), cache

    # -- dispatch routing --------------------------------------------------
    # With a tiered store the jitted decode/prefill receive the R-row resident
    # bank and *resident row indices*; without one, the dense U-user bank and
    # global user ids. Shapes are stable either way, so jit caches one variant.
    def _dispatch_bank(self):
        return self.store.bank if self.store is not None else self.bank

    def _dispatch_idx(self) -> np.ndarray:
        return self.res_idx if self.store is not None else self.users

    # -- engine ------------------------------------------------------------
    def _validate(self, req: Request) -> str | None:
        if len(req.prompt) == 0:
            return "empty prompt"
        # a prompt occupies positions [0, P); at least one decode tick must fit
        # below the cache horizon, so max_prompt is capped at max_len - 1
        if len(req.prompt) > self.max_prompt:
            return (f"prompt length {len(req.prompt)} > max_prompt "
                    f"{self.max_prompt} (horizon max_len={self.max_len})")
        if req.max_new <= 0:
            return f"max_new must be positive, got {req.max_new}"
        if self.store is not None:
            if not self.store.knows(req.user):
                return (f"unknown user {req.user} (store has "
                        f"{len(self.store.users())})")
        elif self.bank is not None and not 0 <= req.user < self.n_users:
            return f"unknown user {req.user} (bank has {self.n_users})"
        return None

    def submit(self, req: Request) -> None:
        """Queue a request — or reject it with a terminal status (bad requests
        must never crash a tick or occupy a slot)."""
        req.t_submit = time.perf_counter()
        reason = self._validate(req)
        if reason is not None:
            req.status = f"rejected: {reason}"
            req.done = True
            req.t_done = req.t_submit
            self.stats["rejected"] += 1
            self.finished.append(req)
            return
        self.queue.append(req)

    # -- adapter bank lifecycle ---------------------------------------------
    def install_adapters(self, user: int, adapters: dict, version: int) -> bool:
        with self._span("serve.bank_install", user=user, version=version):
            ok = self._install_adapters(user, adapters, version)
        self._record("user", user, "bank_install", version=version, ok=ok)
        return ok

    def _install_adapters(self, user: int, adapters: dict, version: int) -> bool:
        """Hot-swap one user's adapters into the serving bank.

        Accepts only *validated version bumps*: the version must exceed the
        user's installed version and every leaf must be finite — anything else
        is rejected and the user keeps serving their last-good adapters
        (graceful degradation for quarantined / stale users). Returns whether
        the bank was installed.

        With a tiered store, the commit lands in the host tier (registering
        brand-new users); a clustered user is split off their shared adapter
        (copy-on-write) without perturbing other members, and a live user's
        resident row is refreshed in place.
        """
        if self.store is not None:
            return self._install_store(user, adapters, version)
        if self.bank is None or not 0 <= user < self.n_users:
            self.stats["bank_rejected"] += 1
            return False
        if version <= int(self.bank_versions[user]):
            self.stats["bank_rejected"] += 1   # stale or replayed update
            return False
        leaves = jax.tree.leaves(adapters)
        if not all(bool(jnp.isfinite(l).all()) for l in leaves):
            self.stats["bank_rejected"] += 1   # unvalidated/poisoned bank
            return False
        if set(adapters) != set(self.bank):
            self.stats["bank_rejected"] += 1   # wrong tap set for this bank
            return False
        new_bank = {}
        for tap, entry in self.bank.items():
            new_entry = dict(entry)
            for name, leaf in adapters[tap].items():
                user_slot = ((slice(None), user) if leaf.ndim > 2 else user)
                if f"{name}_q" in entry:
                    # int8-stored bank: quantise the incoming f32 leaf and
                    # swap in both the codes and the per-row scales.
                    from repro.kernels import multi_lora as ml
                    q, s = ml.quant_rows(jnp.asarray(leaf, jnp.float32))
                    stacked_q = entry[f"{name}_q"]
                    if q.shape != stacked_q[user_slot].shape:
                        self.stats["bank_rejected"] += 1
                        return False
                    new_entry[f"{name}_q"] = stacked_q.at[user_slot].set(q)
                    new_entry[f"{name}_scale"] = (
                        entry[f"{name}_scale"].at[user_slot].set(s))
                    continue
                stacked = entry[name]
                if leaf.shape != stacked[user_slot].shape:
                    self.stats["bank_rejected"] += 1
                    return False
                new_entry[name] = stacked.at[user_slot].set(leaf)
            new_bank[tap] = new_entry
        self.bank = new_bank
        self.bank_versions[user] = version
        self.stats["bank_installs"] += 1
        return True

    def _install_store(self, user: int, adapters: dict, version: int) -> bool:
        """Tiered-store install: host-tier commit + in-place resident-row
        refresh. Unknown users are registered (they become servable without
        any restack); known users need a version bump and finite leaves."""
        st = self.store
        leaves = jax.tree.leaves(adapters)
        if not all(bool(jnp.isfinite(l).all()) for l in leaves):
            self.stats["bank_rejected"] += 1   # unvalidated/poisoned bank
            return False
        try:
            if not st.knows(user):
                st.register(user, adapters, version=version)
            else:
                if version <= st.version(user):
                    self.stats["bank_rejected"] += 1   # stale or replayed
                    return False
                st.install(user, adapters, version)
        except ValueError:   # wrong tap set / leaf shapes for this store
            self.stats["bank_rejected"] += 1
            return False
        self.stats["bank_installs"] += 1
        # A COW split moves the user onto a fresh host entry while their live
        # slots still point at the old (cluster) row: re-resolve residency now
        # if a row is free/evictable, else their in-flight requests finish on
        # the old adapters and residency refreshes at the next admission.
        live = [i for i, r in enumerate(self.active)
                if r is not None and r.user == user]
        if live:
            try:
                row = st.ensure_resident([user])[0]
            except RuntimeError:
                pass
            else:
                for i in live:
                    self.res_idx[i] = row
        return True

    def _reserve_len(self, req: Request) -> int:
        """Worst-case positions ``req`` can ever write on its slot: the
        chunk-padded prompt (non-recurrent chunk rounds write width-C tails)
        or the decode horizon, whichever is larger, clipped to max_len.
        Reserving this at admission means mid-flight ``ensure`` never fails."""
        P = len(req.prompt)
        C = self.prefill_chunk or P
        padded = -(-P // C) * C
        return min(self.max_len, max(padded, P + req.max_new))

    def _table(self):
        return jnp.asarray(self.pager.table) if self.pager is not None else None

    def _admit(self) -> None:
        """Admit up to ``admit_batch`` waiting requests into free slots. The
        unchunked batched path pads all admitted prompts to one (J, P) batch
        and runs a single prefill forward; the reference path feeds tokens one
        by one through the (live-masked) decode step; the chunked path only
        assigns slots (and reserves KV blocks) — chunk rounds in subsequent
        ticks stream the prompts in. All paths emit each request's first
        generated token from the prompt's own logits, never a decode tick."""
        admitted: list[int] = []
        now = time.perf_counter()
        for i in range(self.slots):
            if len(admitted) >= self.admit_batch or not self.queue:
                break
            if self.active[i] is not None:
                continue
            req = self.queue[0]
            if (self.pager is not None
                    and not self.pager.reserve(i, self._reserve_len(req))):
                # pool pressure: admission waits (FIFO) until retirements
                # return enough blocks to back this request's worst case.
                break
            if self.store is not None and not self.store.acquire(req.user):
                # every resident row is pinned by a distinct live user:
                # admission waits (FIFO) until a request completes.
                if self.pager is not None:
                    self.pager.release(i)   # roll back the reservation
                break
            self.queue.pop(0)
            req.t_admit = now
            req._consumed = 0
            self.active[i] = req
            self.users[i] = req.user
            self.positions[i] = 0
            admitted.append(i)
        if not admitted:
            return
        if self.store is not None:
            # prefetch-on-admission: residency is ensured (host -> device
            # fetch on miss) before any prefill/decode touches these slots.
            res_rows = self.store.ensure_resident(
                [self.active[i].user for i in admitted])
            for k, i in enumerate(admitted):
                self.res_idx[i] = res_rows[k]
        self.stats["admitted"] += len(admitted)
        for i in admitted:
            r = self.active[i]
            self._record("slot", i, "admit", rid=r.rid, user=r.user,
                         prompt_len=len(r.prompt))
        if self.prefill_chunk is not None:
            return   # chunk rounds (one per tick) do the prefill work
        rows = [(i, np.asarray(self.active[i].prompt, np.int32))
                for i in admitted]
        t0 = time.perf_counter()
        if self.prefill_mode == "reference":
            for i, feed in rows:
                nxt = 0
                for t, tok in enumerate(feed):
                    nxt = self._feed(i, int(tok), t)
                self._first_token(i, nxt, time.perf_counter())
        else:
            with self._span("serve.prefill", rows=len(rows)):
                self._prefill_batch(rows)
        dt = time.perf_counter() - t0
        self.stats["prefill_time"] += dt
        self._prefill_s.append(dt)
        self._h_prefill_call.observe(dt)
        self.stats["prefill_calls"] += 1
        self.stats["prefill_tokens"] += sum(len(f) for _, f in rows)
        now = time.perf_counter()
        for i, _ in rows:
            if self.active[i] is not None:
                self._maybe_finish(i, now)

    def _prefill_batch(self, rows: list[tuple[int, np.ndarray]]) -> None:
        if self._recurrent:
            # Recurrent (ssm/conv) state folds in every input token, so a
            # right-padded batch would pollute shorter rows' state: prefill
            # each row at its exact length (still one forward per prompt
            # instead of one decode step per token).
            for i, feed in rows:
                nxt, self.cache = self._prefill(
                    self.params, self._dispatch_bank(), self.cache,
                    jnp.asarray(feed[None, :]),
                    jnp.asarray(self._dispatch_idx()[i:i + 1]),
                    jnp.asarray(np.array([i], np.int32)),
                    jnp.asarray(np.array([len(feed)], np.int32)))
                self._first_token(i, int(np.asarray(nxt)[0]),
                                  time.perf_counter())
            return
        # attention KV: pad-token garbage beyond a row's true length is safe
        # (decode overwrites position p before attending; causality hides > p),
        # so bucket shapes to bound jit recompilation. The bucket never
        # exceeds max_len, which bounds the cache's sequence axis.
        pmax = min(_bucket(max(len(feed) for _, feed in rows)), self.max_len)
        j = _bucket(len(rows), floor=1)
        toks = np.zeros((j, pmax), np.int32)
        users = np.zeros((j,), np.int32)
        lengths = np.ones((j,), np.int32)
        # padding rows point at slot id == slots (out of range -> dropped)
        slot_ids = np.full((j,), self.slots, np.int32)
        for r, (i, feed) in enumerate(rows):
            toks[r, :len(feed)] = feed
            users[r] = self._dispatch_idx()[i]
            slot_ids[r] = i
            lengths[r] = len(feed)
        nxt, self.cache = self._prefill(self.params, self._dispatch_bank(),
                                        self.cache, jnp.asarray(toks),
                                        jnp.asarray(users),
                                        jnp.asarray(slot_ids),
                                        jnp.asarray(lengths))
        nxt = np.asarray(nxt)
        now = time.perf_counter()
        for r, (i, _) in enumerate(rows):
            self._first_token(i, int(nxt[r]), now)

    def _feed(self, slot: int, token: int, pos: int) -> int:
        """Reference single-row prefill step: decode one prompt token into one
        slot's cache and return the argmax token (the last feed's return is
        the request's first generated token). The live mask confines the cache
        write to ``slot`` (the unmasked version corrupted position 0 of every
        other live slot)."""
        toks = np.zeros((self.slots, 1), np.int32)
        toks[slot, 0] = token
        positions = np.zeros((self.slots,), np.int32)
        positions[slot] = pos
        live = np.zeros((self.slots,), bool)
        live[slot] = True
        nxt, self.cache = self._decode(self.params, self._dispatch_bank(),
                                       self.cache, None, jnp.asarray(toks),
                                       jnp.asarray(positions),
                                       jnp.asarray(self._dispatch_idx()),
                                       jnp.asarray(live))
        return int(np.asarray(nxt)[slot])

    def _first_token(self, i: int, tok: int, now: float) -> None:
        """Record a request's first generated token (emitted from its prompt's
        own logits at prefill/chunk completion) and arm the slot for decode:
        the next decode tick feeds this token at position P."""
        req = self.active[i]
        req.t_first = now
        req.out.append(tok)
        req._last = tok
        req._consumed = len(req.prompt)   # prompt fully in cache: decode-live
        self.positions[i] = len(req.prompt)
        self.stats["tokens"] += 1
        self._h_ttft.observe(now - req.t_submit)
        self._record("slot", i, "first_token", rid=req.rid, user=req.user,
                     ttft=now - req.t_submit)

    def _maybe_finish(self, i: int, now: float) -> None:
        req = self.active[i]
        if (len(req.out) >= req.max_new
                or self.positions[i] >= self.max_len - 1):
            self._retire(i, now)

    def _retire(self, i: int, now: float) -> None:
        req = self.active[i]
        req.done = True
        req.status = "done"
        req.t_done = now
        self.stats["completed"] += 1
        if req.latency is not None:
            self._h_latency.observe(req.latency)
        self._record("slot", i, "retire", rid=req.rid, user=req.user,
                     new_tokens=len(req.out))
        self.finished.append(req)
        self.active[i] = None
        self.positions[i] = 0
        if self.pager is not None:
            self.pager.release(i)
        if self.store is not None:
            self.store.release(req.user)

    def _chunk_round(self) -> list[int]:
        """Advance every mid-prefill slot by one chunk (Sarathi interleave:
        exactly one round per tick, so a long prompt costs each decode tick at
        most one chunk of extra model work). Non-recurrent rows run as one
        width-C padded group; recurrent rows are grouped by exact chunk width
        so padding never touches conv/ssd state. Returns the slots that were
        mid-prefill at entry."""
        pend = [i for i, r in enumerate(self.active)
                if r is not None and r._consumed < len(r.prompt)]
        if not pend:
            return pend
        C = self.prefill_chunk
        t0 = time.perf_counter()
        if self._recurrent:
            groups: dict[int, list[int]] = {}
            for i in pend:
                req = self.active[i]
                groups.setdefault(min(C, len(req.prompt) - req._consumed),
                                  []).append(i)
            todo = sorted(groups.items())
        else:
            todo = [(C, pend)]
        for width, idx_list in todo:
            toks = np.zeros((self.slots, width), np.int32)
            lens = np.ones((self.slots,), np.int32)
            live = np.zeros((self.slots,), bool)
            pos = np.zeros((self.slots,), np.int32)
            for i in idx_list:
                req = self.active[i]
                c = min(width, len(req.prompt) - req._consumed)
                toks[i, :c] = req.prompt[req._consumed:req._consumed + c]
                lens[i] = c
                live[i] = True
                pos[i] = req._consumed
                if self.pager is not None:
                    ok = self.pager.ensure(
                        i, min(req._consumed + width - 1, self.max_len - 1))
                    assert ok, "admission reservation must cover the prompt"
            nxt, self.cache = self._chunk(
                self.params, self._dispatch_bank(), self.cache, self._table(),
                jnp.asarray(toks), jnp.asarray(pos),
                jnp.asarray(self._dispatch_idx()), jnp.asarray(live),
                jnp.asarray(lens))
            nxt = np.asarray(nxt)
            now = time.perf_counter()
            for i in idx_list:
                req = self.active[i]
                c = min(width, len(req.prompt) - req._consumed)
                req._consumed += c
                self.stats["prefill_tokens"] += c
                if req._consumed >= len(req.prompt):
                    self._first_token(i, int(nxt[i]), now)
                    self._maybe_finish(i, now)
            self.stats["prefill_chunks"] += len(idx_list)
        self.stats["chunk_rounds"] += 1
        dt = time.perf_counter() - t0
        self.stats["prefill_time"] += dt
        self._prefill_s.append(dt)
        self._h_prefill_chunk.observe(dt)
        return pend

    def _burst_len(self, live_idx: list[int]) -> int:
        """Largest safe burst: no live slot may complete (or first-token) inside
        a burst, so the host loop only ever observes burst boundaries. Burst
        sizes are powers of two to bound jit recompilations to log2 variants."""
        if self.decode_burst <= 1:
            return 1
        bound = self.decode_burst
        for i in live_idx:
            req = self.active[i]
            remaining = min(req.max_new - len(req.out),
                            self.max_len - 1 - int(self.positions[i]))
            bound = min(bound, remaining)
        if bound <= 1:
            return 1
        n = 1
        while n * 2 <= bound:
            n *= 2
        return n

    def tick(self) -> int:
        """One engine iteration: admit, advance mid-prefill slots by one chunk
        (chunked mode), then decode one token for every slot whose prompt is
        fully in cache (or a burst when ``decode_burst`` allows; bursts are
        capped to 1 while any slot is prefilling so the chunk interleave — and
        with it decode latency — stays per-tick flat)."""
        with self._span("serve.tick", tick=self.stats["ticks"]):
            return self._tick_inner()

    def _tick_inner(self) -> int:
        if self.queue:
            with self._span("serve.admit", queued=len(self.queue)):
                self._admit()
        prefilling: list[int] = []
        if self.prefill_chunk is not None and any(
                r is not None and r._consumed < len(r.prompt)
                for r in self.active):
            with self._span("serve.prefill_chunk"):
                prefilling = self._chunk_round()
        live_idx = [i for i, r in enumerate(self.active)
                    if r is not None and r._consumed >= len(r.prompt)]
        if not live_idx:
            if prefilling:
                self.stats["ticks"] += 1
            self._sync_store_stats()
            self._sync_pager_stats()
            return 0
        toks = np.zeros((self.slots, 1), np.int32)
        live = np.zeros((self.slots,), bool)
        for i in live_idx:
            toks[i, 0] = self.active[i]._last
            live[i] = True
        n = 1 if prefilling else self._burst_len(live_idx)
        if self.pager is not None:
            for i in live_idx:
                ok = self.pager.ensure(
                    i, min(int(self.positions[i]) + n - 1, self.max_len - 1))
                assert ok, "admission reservation must cover the horizon"
        bank = self._dispatch_bank()
        idx = jnp.asarray(self._dispatch_idx())
        table = self._table()
        t0 = time.perf_counter()
        with self._span("serve.decode", live=len(live_idx), burst=n), \
                annotate("serve.decode"):
            if n <= 1:
                nxt, self.cache = self._decode(self.params, bank, self.cache,
                                               table, jnp.asarray(toks),
                                               jnp.asarray(self.positions),
                                               idx, jnp.asarray(live))
                trace = np.asarray(nxt)[None]                  # (1, slots)
            else:
                trace, self.cache = self._decode_n(self.params, bank,
                                                   self.cache, table,
                                                   jnp.asarray(toks),
                                                   jnp.asarray(self.positions),
                                                   idx, jnp.asarray(live),
                                                   n=n)
                trace = np.asarray(trace)                      # (n, slots)
        now = time.perf_counter()
        self.stats["decode_time"] += now - t0
        # one sample per tick decoded: a burst's dispatch wall is split evenly
        # so percentiles stay comparable across decode_burst settings
        self._decode_tick_s.append((now - t0) / trace.shape[0])
        self._h_decode_tick.observe((now - t0) / trace.shape[0])
        for step in range(trace.shape[0]):
            for i in live_idx:
                req = self.active[i]
                tok = int(trace[step, i])
                req.out.append(tok)
                req._last = tok
                self.positions[i] += 1
        for i in live_idx:
            self._maybe_finish(i, now)
        self.stats["ticks"] += trace.shape[0]
        self.stats["tokens"] += trace.shape[0] * len(live_idx)
        self.stats["decode_tokens"] += trace.shape[0] * len(live_idx)
        self._sync_store_stats()
        self._sync_pager_stats()
        return trace.shape[0] * len(live_idx)

    def run_until_idle(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.active):
                break
            self.tick()

    # -- stats -------------------------------------------------------------
    def _sync_store_stats(self) -> None:
        """Mirror the adapter store's counters/gauges into ``engine.stats``."""
        if self.store is None:
            return
        m = self.store.metrics()
        self.stats["store_hits"] = m["hits"]
        self.stats["store_misses"] = m["misses"]
        self.stats["store_evictions"] = m["evictions"]
        self.stats["store_hit_rate"] = m["hit_rate"]
        self.stats["store_pinned"] = m["pinned"]
        self.stats["store_resident_bytes"] = m["resident_bytes"]
        self.stats["store_fetch_time"] = m["fetch_time"]

    def _sync_pager_stats(self) -> None:
        """Mirror the KV block pool's counters/gauges into ``engine.stats``."""
        if self.pager is None:
            return
        p = self.pager.stats
        self.stats["kv_blocks_in_use"] = p["in_use"]
        self.stats["kv_blocks_peak"] = p["peak_in_use"]
        self.stats["kv_allocs"] = p["allocs"]
        self.stats["kv_frees"] = p["frees"]
        self.stats["kv_reserve_failures"] = p["reserve_failures"]

    def kv_cache_bytes(self) -> int:
        """Decode-cache bytes attributable to current load. Dense: every leaf
        in full (the slot cache is the footprint, occupied or not). Paged:
        pool leaves are charged per *used* block — the quantity that scales
        with tokens held and that capacity planning sizes the pool by — plus
        the non-pool leaves (rings, recurrent state, block table) in full."""
        total = pool = 0
        for leaf in jax.tree.leaves(self.cache):
            nbytes = leaf.size * leaf.dtype.itemsize
            total += nbytes
            if (self.pager is not None and leaf.ndim == 5
                    and leaf.shape[1] == self.pager.n_blocks
                    and leaf.shape[2] == self.pager.block_size):
                pool += nbytes
        if self.pager is None:
            return total
        per_block = pool // max(self.pager.n_blocks, 1)
        table_bytes = self.pager.table.size * self.pager.table.itemsize
        return ((total - pool) + per_block * self.pager.blocks_in_use()
                + table_bytes)

    def request_stats(self) -> list[dict]:
        """Per-completed-request latency metrics (seconds)."""
        return [{"rid": r.rid, "user": r.user, "prompt_len": len(r.prompt),
                 "new_tokens": len(r.out), "ttft": r.ttft,
                 "latency": r.latency} for r in self.finished]

    def throughput(self) -> dict:
        """Aggregate engine throughput; decode tokens/sec excludes prefill.

        Tail latency rides along: ``ttft`` / ``latency`` summarise completed
        requests, ``decode_tick`` / ``prefill`` the per-dispatch duration
        rings (each is None or {count, mean, max, p50, p95, p99} seconds —
        means hide stalls, so report the percentiles, not ``mean_ttft``)."""
        dt = self.stats["decode_time"]
        pt = self.stats["prefill_time"]
        reqs = self.request_stats()
        ttfts = [r["ttft"] for r in reqs if r["ttft"] is not None]
        lats = [r["latency"] for r in reqs if r["latency"] is not None]
        self._sync_store_stats()
        self._sync_pager_stats()
        out = {
            "decode_tok_per_s": (self.stats["decode_tokens"] / dt
                                 if dt else 0.0),
            "prefill_tok_per_s": (self.stats["prefill_tokens"] / pt
                                  if pt else 0.0),
            "mean_ttft": float(np.mean(ttfts)) if ttfts else None,
            "ttft": percentiles(ttfts),
            "latency": percentiles(lats),
            "decode_tick": percentiles(self._decode_tick_s),
            "prefill": percentiles(self._prefill_s),
            "completed": self.stats["completed"],
        }
        if self.store is not None:
            out["store"] = self.store.metrics()
        if self.pager is not None:
            out["kv_blocks_in_use"] = self.pager.blocks_in_use()
            out["kv_blocks_peak"] = self.pager.stats["peak_in_use"]
        return out
