"""Batched serving engine with continuous batching and multi-user adapters —
the inference half of FTaaS: one base model, K users' adapters applied
per-request inside one decode batch (multi-LoRA; the ``multi_lora`` Pallas
kernel's job on TPU).

Design: fixed decode slots. Each slot holds (request id, user id, position,
done). Admission fills free slots from the queue and runs a single-row prefill
into the shared cache; every engine tick decodes one token for all live slots.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import gl
from repro.core import taps as taps_lib
from repro.models import model as model_lib

Array = jax.Array


@dataclasses.dataclass
class Request:
    rid: int
    user: int
    prompt: np.ndarray          # (P,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


def stack_user_adapters(adapter_list: list[dict]) -> dict:
    """K per-user adapter pytrees {tap: {"A": (L?,d,r), "B": ...}} -> multi
    bank {tap: {"A": (L?,U,d,r), ...}} (user axis after any layer axis)."""
    out: dict[str, Any] = {}
    for tap in adapter_list[0]:
        leaves = {}
        for name in adapter_list[0][tap]:
            stacked = jnp.stack([a[tap][name] for a in adapter_list], axis=0)
            if adapter_list[0][tap][name].ndim > 2:   # (L, d, r) -> (L, U, d, r)
                stacked = jnp.moveaxis(stacked, 0, 1)
            leaves[name] = stacked
        out[tap] = leaves
    return out


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: dict, *, slots: int = 8,
                 max_len: int = 512, user_adapters: list[dict] | None = None,
                 taps: str = "qv", scale: float = 1.0):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.queue: list[Request] = []
        self.active: list[Request | None] = [None] * slots
        self.positions = np.zeros(slots, np.int32)
        self.users = np.zeros(slots, np.int32)
        self.cache = model_lib.init_cache(cfg, slots, max_len)
        self.spec = None
        self.bank = None
        if user_adapters:
            tap_names = gl.select_taps(cfg, taps)
            self.spec = taps_lib.make_spec(family="multi_lowrank",
                                           taps=tap_names, scale=scale)
            self.bank = stack_user_adapters(user_adapters)
        self._decode = jax.jit(self._decode_fn)
        self.stats = {"ticks": 0, "tokens": 0, "completed": 0}

    # -- jitted core -----------------------------------------------------
    def _cola_vars(self, users: Array) -> dict | None:
        if self.bank is None:
            return None
        vars_ = {}
        for tap, leaves in self.bank.items():
            entry = dict(leaves)
            a = leaves["A"]
            if a.ndim == 4:   # stacked (L, U, d, r): idx must carry the layer
                entry["idx"] = jnp.broadcast_to(users, (a.shape[0],) + users.shape)
            else:
                entry["idx"] = users
            vars_[tap] = entry
        return {"adapters": vars_}

    def _decode_fn(self, params, cache, tokens, positions, users):
        batch = {"tokens": tokens, "positions": positions}
        logits, cache = model_lib.decode_step(
            self.cfg, params, batch, cache, self.spec, self._cola_vars(users))
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache

    # -- engine ------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                req = self.queue.pop(0)
                self.active[i] = req
                self.users[i] = req.user
                # single-row prefill: feed prompt tokens one by one (simple and
                # correct; a batched prefill path is the obvious optimisation)
                for t, tok in enumerate(req.prompt[:-1]):
                    self._feed(i, int(tok), t)
                self.positions[i] = len(req.prompt) - 1
                req._last = int(req.prompt[-1])

    def _feed(self, slot: int, token: int, pos: int) -> None:
        toks = np.zeros((self.slots, 1), np.int32)
        toks[slot, 0] = token
        positions = np.full((self.slots,), 0, np.int32)
        positions[slot] = pos
        _, self.cache = self._decode(self.params, self.cache,
                                     jnp.asarray(toks), jnp.asarray(positions),
                                     jnp.asarray(self.users))

    def tick(self) -> int:
        """One engine iteration: admit + decode one token for all live slots."""
        self._admit()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        toks = np.zeros((self.slots, 1), np.int32)
        for i in live:
            toks[i, 0] = self.active[i]._last
        nxt, self.cache = self._decode(self.params, self.cache,
                                       jnp.asarray(toks),
                                       jnp.asarray(self.positions),
                                       jnp.asarray(self.users))
        nxt = np.asarray(nxt)
        for i in live:
            req = self.active[i]
            tok = int(nxt[i])
            req.out.append(tok)
            req._last = tok
            self.positions[i] += 1
            if len(req.out) >= req.max_new or self.positions[i] >= self.max_len - 1:
                req.done = True
                self.stats["completed"] += 1
                self.active[i] = None
                self.positions[i] = 0
        self.stats["ticks"] += 1
        self.stats["tokens"] += len(live)
        return len(live)

    def run_until_idle(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.active):
                break
            self.tick()
