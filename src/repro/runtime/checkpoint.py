"""Checkpointing: atomic, asynchronous, retention-managed, elastic.

Format: one ``.npz`` per checkpoint holding the flattened pytree (keys are
dotted paths) + a JSON meta sidecar. Writes go to a temp file and are
``os.replace``d into place, so a crash mid-write never corrupts the latest
checkpoint. ``save_async`` runs the serialisation on a worker thread so the
train loop's dispatch is never blocked (overlap with the next step's compute).

Elastic restore: arrays are stored unsharded (host RAM); ``restore`` returns
numpy trees that can be ``device_put`` onto ANY mesh — growing or shrinking the
cluster between runs only changes the shardings applied on load.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any

import jax
import numpy as np

from repro.utils import flatten_dict, unflatten_dict

PyTree = Any


# NOTE: tap names contain dots ("layers.attn.q"), so the flatten separator
# must be something that cannot appear in a dict key.
_SEP = "/"


def _to_numpy_tree(tree: PyTree) -> dict[str, np.ndarray]:
    flat = (flatten_dict(tree, sep=_SEP) if isinstance(tree, dict)
            else {"__root__": tree})
    out = {}
    for k, v in flat.items():
        arr = np.asarray(v)
        if arr.dtype == np.dtype("bfloat16"):
            out["bf16::" + k] = arr.view(np.uint16)
        else:
            out[k] = arr
    return out


def _from_numpy_tree(d: dict[str, np.ndarray]) -> PyTree:
    import ml_dtypes
    out = {}
    for k, v in d.items():
        if k.startswith("bf16::"):
            out[k[len("bf16::"):]] = v.view(ml_dtypes.bfloat16)
        else:
            out[k] = v
    if set(out) == {"__root__"}:
        return out["__root__"]
    return unflatten_dict(out, sep=_SEP)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # -- paths ---------------------------------------------------------
    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:010d}.npz")

    def steps(self) -> list[int]:
        out = []
        for f in os.listdir(self.dir):
            if f.startswith("ckpt_") and f.endswith(".npz"):
                try:
                    out.append(int(f[5:-4]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # -- save ----------------------------------------------------------
    def save(self, step: int, tree: PyTree, meta: dict | None = None) -> str:
        path = self._path(step)
        tmp = path + f".tmp.{os.getpid()}"
        with open(tmp, "wb") as f:      # file handle: savez must not append .npz
            np.savez(f, **_to_numpy_tree(tree))
        os.replace(tmp, path)
        with open(path + ".meta.json.tmp", "w") as f:
            json.dump({"step": step, "time": time.time(), **(meta or {})}, f)
        os.replace(path + ".meta.json.tmp", path + ".meta.json")
        self._gc()
        return path

    def save_async(self, step: int, tree: PyTree, meta: dict | None = None):
        """Snapshot to host (blocks only for device->host copy), then write on
        a background thread."""
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)
        self._thread = threading.Thread(
            target=self._save_guarded, args=(step, host_tree, meta), daemon=True)
        self._thread.start()

    def _save_guarded(self, step, tree, meta):
        try:
            self.save(step, tree, meta)
        except Exception as e:  # surfaced on next wait()
            self._error = e

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            for suffix in (".npz", ".npz.meta.json"):
                try:
                    os.remove(os.path.join(self.dir, f"ckpt_{s:010d}" + suffix))
                except OSError:
                    pass

    # -- restore ---------------------------------------------------------
    def restore(self, step: int | None = None,
                shardings: PyTree | None = None) -> tuple[int, PyTree] | None:
        step = self.latest_step() if step is None else step
        if step is None:
            return None
        with np.load(self._path(step), allow_pickle=False) as z:
            tree = _from_numpy_tree({k: z[k] for k in z.files})
        if shardings is not None:
            tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree,
                                shardings)
        return step, tree
