"""Fault-tolerant training loop.

Guarantees:
- restartable: state = (step counter, params/adapters/optimizer) — the data
  pipeline is a pure function of the step, so a restart resumes exactly.
- crash-safe checkpoints: atomic writes, async serialisation, retention.
- preemption handling: SIGTERM triggers checkpoint-and-exit at the next step
  boundary (the TPU preemption notice pattern).
- straggler monitoring via the Watchdog; metrics stream to JSONL.
"""
from __future__ import annotations

import json
import os
import signal
import time
from typing import Any, Callable

import numpy as np

from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.watchdog import Watchdog


class TrainLoop:
    def __init__(self, session, data, workdir: str, *, ckpt_every: int = 50,
                 log_every: int = 10, keep: int = 3,
                 eval_fn: Callable[[int], dict] | None = None,
                 eval_every: int = 0, recover_on_straggler: bool = False,
                 telemetry=None):
        self.session = session
        self.data = data
        self.workdir = workdir
        os.makedirs(workdir, exist_ok=True)
        # telemetry: step-time histogram + straggler postmortems ride through
        # the watchdog; the metric registry streams to telemetry.jsonl next
        # to the (always-on) metrics.jsonl
        self.tm = telemetry if telemetry else None
        if self.tm:
            self.tm.registry.stream_to(
                os.path.join(workdir, "telemetry.jsonl"))
        self.ckpt = CheckpointManager(os.path.join(workdir, "ckpt"), keep=keep)
        self.watchdog = Watchdog(
            heartbeat_path=os.path.join(workdir, "heartbeat.json"),
            on_straggler=self._on_straggler if recover_on_straggler else None,
            telemetry=self.tm)
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.eval_fn = eval_fn
        self.eval_every = eval_every
        self.metrics_path = os.path.join(workdir, "metrics.jsonl")
        self._preempted = False
        self.losses: list[float] = []
        self.recoveries = 0

    # -- straggler / hang recovery ------------------------------------------
    def _on_straggler(self, step: int, dt: float, med: float) -> None:
        """A straggling/hung step signals a sick offload round: checkpoint the
        last-good state and reset the offload channels (drop in-flight
        buffers, restore last-good banks, lift quarantine)."""
        self.recoveries += 1
        if self.tm:
            self.tm.record("train", 0, "recovery", step=step, dt=dt,
                           median=med)
        self.ckpt.save_async(step, self._state())
        reset = getattr(self.session, "reset_channels", None)
        if reset is not None:
            reset()

    # -- telemetry ----------------------------------------------------------
    def _channel_briefs(self) -> dict:
        """Per-user compact channel health (empty for channel-less modes)."""
        chs = getattr(self.session, "channels", None)
        if chs is None:
            ch = getattr(self.session, "channel", None)
            chs = [ch] if ch is not None else []
        return {ch.user: ch.health_brief() for ch in chs}

    def _emit_telemetry(self, step: int, loss: float) -> None:
        """Absorb the train-side stat dicts into the registry (``train.*`` /
        ``channel.*``) and append one snapshot to telemetry.jsonl."""
        if self.tm is None:
            return
        reg = self.tm.registry
        reg.absorb("train", {"step": step, "loss": float(loss),
                             "recoveries": self.recoveries})
        reg.absorb("train.watchdog", self.watchdog.stats)
        for user, brief in self._channel_briefs().items():
            reg.absorb(f"channel.u{user}", brief)
        reg.emit(step=step)

    # -- state (de)hydration -------------------------------------------
    def _state(self) -> dict:
        s = {"step": np.asarray(self.session.step_count)}
        if hasattr(self.session, "adapters") and self.session.adapters:
            s["adapters"] = self.session.adapters
            if hasattr(self.session, "offloader"):
                s["opt_state"] = self.session.offloader.opt_state
            elif hasattr(self.session, "opt_state"):
                s["opt_state"] = self.session.opt_state
        else:
            s["params"] = self.session.base_params
            if hasattr(self.session, "opt_state"):
                s["opt_state"] = self.session.opt_state
        return s

    def _load_state(self, tree: dict) -> None:
        import jax
        self.session.step_count = int(tree["step"])
        if "adapters" in tree:
            ad = jax.tree.map(jax.numpy.asarray, tree["adapters"])
            self.session.adapters = ad
            if hasattr(self.session, "offloader"):
                self.session.offloader.adapters = ad
                self.session.offloader.opt_state = jax.tree.map(
                    jax.numpy.asarray, tree["opt_state"])
            elif hasattr(self.session, "opt_state"):
                self.session.opt_state = jax.tree.map(
                    jax.numpy.asarray, tree["opt_state"])
            if getattr(self.session, "_merged_cache", None) is not None:
                self.session._merged_cache = None
        else:
            self.session.base_params = jax.tree.map(
                jax.numpy.asarray, tree["params"])
            if "opt_state" in tree and hasattr(self.session, "opt_state"):
                self.session.opt_state = jax.tree.map(
                    jax.numpy.asarray, tree["opt_state"])

    # -- preemption -------------------------------------------------------
    def _install_signal_handler(self):
        def handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # not on main thread (tests)

    # -- run ---------------------------------------------------------------
    def run(self, steps: int, resume: bool = True) -> dict:
        self._install_signal_handler()
        if resume:
            restored = self.ckpt.restore()
            if restored is not None:
                _, tree = restored
                self._load_state(tree)
                print(f"[train] resumed from step {self.session.step_count}")

        start = self.session.step_count
        t_begin = time.time()
        with open(self.metrics_path, "a") as mf:
            for step in range(start, steps):
                self.watchdog.start_step()
                batch = self.data.batch_at(step)
                loss = self.session.step(batch)
                dt = self.watchdog.end_step(step)
                self.losses.append(loss)
                if step % self.log_every == 0 or step == steps - 1:
                    rec = {"step": step, "loss": loss, "dt": round(dt, 4),
                           "watchdog": self.watchdog.brief(),
                           "channel_health": self._channel_briefs()}
                    if self.eval_every and self.eval_fn and \
                            step % self.eval_every == 0:
                        rec.update(self.eval_fn(step))
                    mf.write(json.dumps(rec) + "\n")
                    mf.flush()
                    self._emit_telemetry(step, loss)
                if (step + 1) % self.ckpt_every == 0 or self._preempted:
                    self.ckpt.save_async(step + 1, self._state())
                if self._preempted:
                    self.ckpt.wait()
                    print(f"[train] preempted at step {step}; checkpointed")
                    break
        self.ckpt.save_async(self.session.step_count, self._state())
        self.ckpt.wait()
        out = {
            "steps": self.session.step_count - start,
            "final_loss": self.losses[-1] if self.losses else None,
            "wall_s": time.time() - t_begin,
            "stragglers": len(self.watchdog.stragglers),
            "recoveries": self.recoveries,
            "heartbeat_failures": self.watchdog.stats["heartbeat_failures"],
            "watchdog": self.watchdog.summary(),
        }
        health = getattr(self.session, "channel_health", None)
        if health is not None:
            out["channel_health"] = health()
        return out
