"""Step-time watchdog: straggler detection + heartbeat for fault tolerance.

At pod scale a straggling host shows up as a step-time outlier; the watchdog
tracks a robust running median and flags steps slower than ``threshold`` x the
median. Recovery hooks: callbacks can trigger a checkpoint, drop the offending
data shard, reset the offload channels, or request elastic down-scale (the
train loop wires these in). The heartbeat file lets an external supervisor
detect a hung process (the standard preemption/zombie pattern on TPU pods);
heartbeat write failures (full/read-only disk) are counted in ``stats`` rather
than crashing the training step — losing a heartbeat must never lose the job.
"""
from __future__ import annotations

import collections
import json
import os
import time
from typing import Callable

from repro.telemetry.metrics import percentiles


class WatchdogError(RuntimeError):
    """Watchdog API misuse (e.g. end_step without a matching start_step)."""


class Watchdog:
    def __init__(self, window: int = 50, threshold: float = 3.0,
                 heartbeat_path: str | None = None,
                 on_straggler: Callable[[int, float, float], None] | None = None,
                 telemetry=None):
        self.window = window
        self.threshold = threshold
        self.heartbeat_path = heartbeat_path
        self.on_straggler = on_straggler
        self.durations: collections.deque[float] = collections.deque(maxlen=window)
        self.stragglers: list[tuple[int, float, float]] = []
        self.stats = {"steps": 0, "heartbeats": 0, "heartbeat_failures": 0}
        self._t0: float | None = None
        # observational: step-time histogram + a per-step breadcrumb ring so
        # a straggler postmortem shows the steps leading up to the outlier
        self.tm = telemetry if telemetry else None

    def start_step(self) -> None:
        self._t0 = time.perf_counter()

    def end_step(self, step: int) -> float:
        if self._t0 is None:
            raise WatchdogError(
                "end_step() called without a matching start_step()")
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self.stats["steps"] += 1
        med = self.median()
        if self.tm is not None:
            self.tm.registry.histogram("train.step_s").observe(dt)
            self.tm.record("train", 0, "step", step=step, dt=dt)
        if med is not None and len(self.durations) >= 10 and dt > self.threshold * med:
            self.stragglers.append((step, dt, med))
            if self.tm is not None:
                self.tm.record("train", 0, "straggler", step=step, dt=dt,
                               median=med)
                self.tm.dump("train", 0,
                             f"straggler step {step}: {dt:.4f}s > "
                             f"{self.threshold:g}x median {med:.4f}s")
            if self.on_straggler:
                self.on_straggler(step, dt, med)
        self.durations.append(dt)
        if self.heartbeat_path:
            try:
                tmp = self.heartbeat_path + ".tmp"
                with open(tmp, "w") as f:
                    json.dump({"step": step, "time": time.time(), "dt": dt}, f)
                os.replace(tmp, self.heartbeat_path)
                self.stats["heartbeats"] += 1
            except OSError:
                # disk full / path gone / read-only fs: a missed heartbeat is
                # an observability gap, not a training failure
                self.stats["heartbeat_failures"] += 1
        return dt

    def median(self) -> float | None:
        if not self.durations:
            return None
        s = sorted(self.durations)
        return s[len(s) // 2]

    def summary(self) -> dict:
        """Step-time health over the sliding window: counters plus tail
        percentiles (``step_s`` is None until a step completes)."""
        out = dict(self.stats)
        out["stragglers"] = len(self.stragglers)
        out["median_s"] = self.median()
        out["step_s"] = percentiles(self.durations)
        return out

    def brief(self) -> dict:
        """Compact record for periodic logging (TrainLoop's metrics.jsonl)."""
        p = percentiles(self.durations)
        return {"steps": self.stats["steps"],
                "stragglers": len(self.stragglers),
                "heartbeat_failures": self.stats["heartbeat_failures"],
                "median_s": self.median(),
                "p95_s": p["p95"] if p else None}
