"""Step-time watchdog: straggler detection + heartbeat for fault tolerance.

At pod scale a straggling host shows up as a step-time outlier; the watchdog
tracks a robust running median and flags steps slower than ``threshold`` x the
median. Recovery hooks: callbacks can trigger a checkpoint, drop the offending
data shard, or request elastic down-scale (the train loop wires these in).
The heartbeat file lets an external supervisor detect a hung process (the
standard preemption/зombie pattern on TPU pods).
"""
from __future__ import annotations

import collections
import json
import os
import time
from typing import Callable


class Watchdog:
    def __init__(self, window: int = 50, threshold: float = 3.0,
                 heartbeat_path: str | None = None,
                 on_straggler: Callable[[int, float, float], None] | None = None):
        self.window = window
        self.threshold = threshold
        self.heartbeat_path = heartbeat_path
        self.on_straggler = on_straggler
        self.durations: collections.deque[float] = collections.deque(maxlen=window)
        self.stragglers: list[tuple[int, float, float]] = []
        self._t0: float | None = None

    def start_step(self) -> None:
        self._t0 = time.perf_counter()

    def end_step(self, step: int) -> float:
        assert self._t0 is not None, "start_step not called"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        med = self.median()
        if med is not None and len(self.durations) >= 10 and dt > self.threshold * med:
            self.stragglers.append((step, dt, med))
            if self.on_straggler:
                self.on_straggler(step, dt, med)
        self.durations.append(dt)
        if self.heartbeat_path:
            tmp = self.heartbeat_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"step": step, "time": time.time(), "dt": dt}, f)
            os.replace(tmp, self.heartbeat_path)
        return dt

    def median(self) -> float | None:
        if not self.durations:
            return None
        s = sorted(self.durations)
        return s[len(s) // 2]
