"""Deterministic fault injection + retry policy for the FTaaS offload channel.

ColA's premise is that gradient fitting is decoupled from the server and
offloaded to low-cost devices — which drop, delay, corrupt and duplicate
payloads in practice. This module models that unreliable transport so the
`OffloadChannel` (repro.core.channel) and the chaos suite (tests/test_faults.py)
can exercise every failure mode reproducibly:

- ``FaultProfile``  : per-user fault probabilities (drop / delay / corrupt /
                      duplicate / NaN-poison), applied to tap payloads and to
                      returned adapter banks.
- ``FaultInjector`` : seeded per-user RNG streams — user k's faults are a pure
                      function of (seed, k, transmission index), so a faulted
                      user never perturbs the randomness (or data) of a healthy
                      one, and every chaos run replays exactly.
- ``RetryPolicy``   : bounded retries with exponential backoff + jitter, a
                      wall-clock timeout for offloaded fit calls and a virtual
                      ``timeout_ticks`` horizon for delayed deliveries.
- ``DeadLetter``    : record of a payload whose retries were exhausted.

Time is modelled two ways on purpose: *transit* latency is virtual (ticks, so
tests never sleep), while *compute* hangs are wall-clock (a hung ``maybe_fit``
is cut off by running it on a worker thread with a timeout).
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
from typing import Any, Callable

import jax
import numpy as np


# ---------------------------------------------------------------------------
# fault taxonomy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultProfile:
    """Per-user fault probabilities for one direction of the channel.

    Probabilities are evaluated in order drop -> delay -> duplicate; corrupt
    and NaN-poison then (independently) mangle whatever is delivered.
    """
    drop: float = 0.0          # payload lost in transit (no ack)
    delay: float = 0.0         # payload arrives ``delay_ticks`` late
    delay_ticks: int = 1       # lateness of a delayed payload (virtual ticks)
    duplicate: float = 0.0     # payload delivered twice (same sequence id)
    corrupt: float = 0.0       # payload values scrambled in transit
    nan: float = 0.0           # payload poisoned with NaNs
    corrupt_scale: float = 1e6  # magnitude of corruption noise
    targets: tuple[str, ...] = ("payload", "adapters")

    def faulty(self) -> bool:
        return any(p > 0 for p in
                   (self.drop, self.delay, self.duplicate, self.corrupt,
                    self.nan))


# canonical single-fault profiles for the chaos matrix
SINGLE_FAULTS = {
    "drop": FaultProfile(drop=0.4),
    "delay": FaultProfile(delay=0.5, delay_ticks=1),
    "corrupt": FaultProfile(corrupt=0.4),
    "duplicate": FaultProfile(duplicate=0.5),
    "nan": FaultProfile(nan=0.4),
}


@dataclasses.dataclass
class Delivery:
    """One copy of a transmitted object as it arrives at the far end."""
    obj: Any
    late_ticks: int = 0        # 0 = on time


def _poison_tree(tree, rng: np.random.Generator, scale: float | None):
    """Corrupt (scale is not None) or NaN-poison (scale is None) one random
    leaf of a payload pytree — the realistic failure is a flipped page or a
    bad DMA, not uniform noise over every tensor."""
    leaves, treedef = jax.tree.flatten(tree)
    idx = int(rng.integers(len(leaves)))

    def mangle(a):
        x = np.array(jax.device_get(a), copy=True)
        if not np.issubdtype(x.dtype, np.floating):
            return a
        flat = x.reshape(-1)
        n = max(1, flat.size // 8)
        pos = rng.choice(flat.size, size=n, replace=False)
        if scale is None:
            flat[pos] = np.nan
        else:
            flat[pos] = (rng.standard_normal(n) * scale).astype(x.dtype)
        return flat.reshape(x.shape)

    leaves = [mangle(l) if i == idx else l for i, l in enumerate(leaves)]
    return jax.tree.unflatten(treedef, leaves)


class FaultInjector:
    """Seeded, per-user fault injection on channel transmissions.

    ``transmit(user, kind, obj)`` returns the list of `Delivery` copies that
    reach the far end for this attempt (possibly empty = dropped, possibly
    two = duplicated, possibly mangled). ``kind`` is "payload" (server ->
    offload device) or "adapters" (offload device -> server); a profile only
    applies to kinds listed in its ``targets``.
    """

    def __init__(self, profiles: dict[int, FaultProfile] | None = None, *,
                 default: FaultProfile | None = None, seed: int = 0,
                 telemetry=None):
        self.profiles = dict(profiles or {})
        self.default = default or FaultProfile()
        self.seed = seed
        self._rngs: dict[int, np.random.Generator] = {}
        self.injected = {"drop": 0, "delay": 0, "duplicate": 0, "corrupt": 0,
                         "nan": 0}
        # when attached, each injected fault leaves a flight-recorder
        # breadcrumb in the target user's ring — a chaos run's postmortems
        # then show the injected cause right next to the channel's reaction.
        # RNG draws are untouched, so seeded replays stay exact.
        self.tm = telemetry if telemetry else None

    def _note(self, user: int, kind: str, fault: str) -> None:
        if self.tm is not None:
            self.tm.record("user", user, "fault_injected", target=kind,
                           fault=fault)

    def profile(self, user: int) -> FaultProfile:
        return self.profiles.get(user, self.default)

    def _rng(self, user: int) -> np.random.Generator:
        if user not in self._rngs:
            self._rngs[user] = np.random.default_rng(
                np.random.SeedSequence((self.seed, user)))
        return self._rngs[user]

    def transmit(self, user: int, kind: str, obj: Any) -> list[Delivery]:
        prof = self.profile(user)
        if kind not in prof.targets or not prof.faulty():
            return [Delivery(obj)]
        rng = self._rng(user)
        r = rng.random()
        if r < prof.drop:
            self.injected["drop"] += 1
            self._note(user, kind, "drop")
            return []
        late = 0
        if r < prof.drop + prof.delay:
            self.injected["delay"] += 1
            self._note(user, kind, "delay")
            late = prof.delay_ticks
        copies = 1
        if rng.random() < prof.duplicate:
            self.injected["duplicate"] += 1
            self._note(user, kind, "duplicate")
            copies = 2
        if rng.random() < prof.corrupt:
            self.injected["corrupt"] += 1
            self._note(user, kind, "corrupt")
            obj = _poison_tree(obj, rng, prof.corrupt_scale)
        if rng.random() < prof.nan:
            self.injected["nan"] += 1
            self._note(user, kind, "nan")
            obj = _poison_tree(obj, rng, None)
        return [Delivery(obj, late_ticks=late) for _ in range(copies)]


# ---------------------------------------------------------------------------
# retry policy + dead letters
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DeadLetter:
    user: int
    seq: int
    kind: str          # "payload" | "fit"
    reason: str
    attempts: int
    payload: Any = None


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retries with exponential backoff + jitter.

    ``timeout_s`` bounds one offloaded *fit* call (wall clock; the call runs on
    a worker thread and is abandoned on timeout). ``timeout_ticks`` bounds how
    late a delayed *delivery* may arrive and still be accepted. Backoff sleeps
    go through ``sleep``, which tests replace with a no-op.
    """
    max_attempts: int = 4
    timeout_s: float | None = None
    timeout_ticks: int = 4
    backoff_base: float = 0.01
    backoff_mult: float = 2.0
    backoff_max: float = 1.0
    jitter: float = 0.25
    seed: int = 0
    sleep: Callable[[float], None] | None = None

    def backoff(self, attempt: int, rng: np.random.Generator) -> float:
        """Backoff (seconds) before retry ``attempt`` (1-based)."""
        base = min(self.backoff_base * self.backoff_mult ** (attempt - 1),
                   self.backoff_max)
        return float(base * (1.0 + self.jitter * rng.random()))

    def wait(self, attempt: int, rng: np.random.Generator) -> float:
        dt = self.backoff(attempt, rng)
        if self.sleep is not None:
            self.sleep(dt)
        return dt


class FitTimeout(Exception):
    """An offloaded fit exceeded RetryPolicy.timeout_s."""


_EXECUTOR: concurrent.futures.ThreadPoolExecutor | None = None


def call_with_timeout(fn: Callable[[], Any], timeout_s: float | None):
    """Run ``fn`` bounded by ``timeout_s`` (None = unbounded, same thread).

    A timed-out fit keeps running on its worker thread (threads cannot be
    killed) but the channel stops waiting — the standard hung-RPC pattern.
    """
    if timeout_s is None:
        return fn()
    global _EXECUTOR
    if _EXECUTOR is None:
        _EXECUTOR = concurrent.futures.ThreadPoolExecutor(
            max_workers=2, thread_name_prefix="offload-fit")
    fut = _EXECUTOR.submit(fn)
    try:
        return fut.result(timeout=timeout_s)
    except concurrent.futures.TimeoutError as e:
        fut.cancel()
        raise FitTimeout(f"offloaded fit exceeded {timeout_s}s") from e
