"""Collective-op breakdown of a compiled module — the 'profile' of the
dry-run perf loop: which collectives, what shapes, how many bytes."""
from __future__ import annotations

import collections
import re

from repro.analysis.roofline import _COLL_RE, _DTYPE_BYTES, _SHAPE_RE


def breakdown(hlo_text: str, top: int = 15) -> list[tuple[str, int, float]]:
    """Returns [(op@shape, count, total_bytes)] sorted by bytes desc."""
    agg: dict[tuple[str, str], list] = collections.defaultdict(lambda: [0, 0.0])
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        shapes = _SHAPE_RE.findall(m.group("shapes"))
        nbytes = 0
        sig = []
        for dtype, dims in shapes:
            b = _DTYPE_BYTES.get(dtype, 0)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * b
            sig.append(f"{dtype}[{dims}]")
        factor = 2.0 if op == "all-reduce" else 1.0
        key = (op, ",".join(sig))
        agg[key][0] += 1
        agg[key][1] += factor * nbytes
    rows = [(f"{op} {sig}", c, b) for (op, sig), (c, b) in agg.items()]
    rows.sort(key=lambda r: -r[2])
    return rows[:top]


def print_breakdown(hlo_text: str, top: int = 15, report=print) -> None:
    total = 0.0
    rows = breakdown(hlo_text, top)
    for name, count, nbytes in rows:
        report(f"  {nbytes/2**30:8.3f} GB  x{count:<4d} {name}")
        total += nbytes
    report(f"  (top-{top} total {total/2**30:.2f} GB per device program)")
