"""Roofline analysis from the compiled dry-run artifact (no real hardware).

Terms per (arch, mesh):
    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (assignment-specified).

``collective_bytes`` is parsed from the HLO text: the summed operand sizes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute ops.
"""
from __future__ import annotations

import re
from typing import Any

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per link per chip

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4,
    "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2,
    "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of one 'dtype[d0,d1,...]' string."""
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dtype, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


_COLL_RE = re.compile(
    r"=\s*(?P<shapes>\(?[\w\[\],{}/:\. ]*?\)?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<suffix>-start)?\(")


def collective_bytes(hlo_text: str, per_op: dict | None = None) -> float:
    """Sum of result-shape bytes of every collective op in the (per-device)
    partitioned HLO. Counted per device: an all-gather's per-device result is
    the full gathered size, which matches the bytes a ring all-gather moves
    through each chip's links; all-reduce moves ~2x its size (reduce-scatter +
    all-gather), folded in with a factor of 2.
    """
    total = 0.0
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        nbytes = 0
        for dtype, dims in _SHAPE_RE.findall(m.group("shapes")):
            b = _DTYPE_BYTES.get(dtype, 0)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * b
        factor = 2.0 if op == "all-reduce" else 1.0
        contrib = factor * nbytes
        total += contrib
        if per_op is not None:
            per_op[op] = per_op.get(op, 0.0) + contrib
    return float(total)


_CONVERT_RE = re.compile(
    r"= f32\[([\d,]+)\][^)]*? convert\((%[\w.\-]+)\)")


def cpu_bf16_emulation_bytes(hlo_text: str, min_bytes: int = 32 << 20) -> int:
    """XLA *CPU* lowers bf16 dots by converting operands to f32; loop-invariant
    code motion hoists those converts, so whole bf16 weight stacks / KV caches
    get persistent f32 shadow copies that would NOT exist on TPU (native bf16
    MXU). This counts the big (>=min_bytes) f32 convert results whose operand
    is a parameter/loop-carried value — the dry-run subtracts them to report a
    TPU-representative peak alongside the raw CPU number."""
    total = 0
    for m in _CONVERT_RE.finditer(hlo_text):
        dims, operand = m.groups()
        if "param" not in operand:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        size = n * 4
        if size >= min_bytes:
            total += size
    return total


def memory_record(mem) -> dict:
    out = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    try:
        out["peak_bytes_per_device"] = int(
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0))
    except Exception:
        pass
    return out


def roofline_terms(rec: dict[str, Any]) -> dict[str, float]:
    """rec carries flops / bytes_accessed / collective_bytes of the PARTITIONED
    per-device module (verified by calibration: cost_analysis of the compiled
    SPMD executable reports one device's program; a 1024^3 matmul reports
    exactly 2*M*N*K). So the terms below are already per-chip — no division by
    the chip count."""
    t_compute = rec["flops"] / PEAK_FLOPS
    t_memory = rec["bytes_accessed"] / HBM_BW
    t_coll = rec["collective_bytes"] / LINK_BW
    terms = {"t_compute": t_compute, "t_memory": t_memory,
             "t_collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    total = max(terms.values())
    return {**terms,
            "bottleneck": bottleneck.replace("t_", ""),
            "roofline_s": total,
            "roofline_fraction": (t_compute / total) if total > 0 else 0.0}


# ---------------------------------------------------------------------------
# MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE); decode uses D=new tokens
# ---------------------------------------------------------------------------

def param_count(cfg) -> tuple[int, int]:
    """(total, active) parameter counts, analytic."""
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab_size
    emb = V * d * (cfg.n_codebooks or 1)
    head = 0 if cfg.tie_embeddings and not cfg.n_codebooks else (
        d * V * (cfg.n_codebooks or 1))
    per_attn = d * (cfg.n_heads * cfg.d_head) * 2 + \
        d * (cfg.n_kv_heads * cfg.d_head) * 2 if cfg.n_heads else 0
    per_mlp = 3 * d * cfg.d_ff if cfg.d_ff else 0
    per_moe_total = per_moe_active = 0
    if cfg.n_experts:
        per_e = 3 * d * cfg.d_expert
        per_moe_total = cfg.n_experts * per_e + d * cfg.n_experts
        per_moe_active = cfg.moe_top_k * per_e + d * cfg.n_experts
    per_ssm = 0
    if cfg.ssm_state:
        di = cfg.ssm_expand * d
        nh = di // cfg.ssm_headdim
        d_in_proj = 2 * di + 2 * cfg.ssm_state + nh
        per_ssm = d * d_in_proj + di * d

    if cfg.family == "ssm":
        body_t = body_a = L * per_ssm
    elif cfg.family == "hybrid":
        n_seg = len(range(0, L, cfg.shared_attn_every))
        shared = per_attn + per_mlp
        body_t = L * per_ssm + shared
        body_a = L * per_ssm + n_seg * shared   # shared block runs n_seg times
    elif cfg.n_experts:
        body_t = L * (per_attn + per_moe_total)
        body_a = L * (per_attn + per_moe_active)
    else:
        body_t = body_a = L * (per_attn + per_mlp)
    return emb + head + body_t, emb + head + body_a


def model_flops(cfg, shape_spec) -> float:
    """Useful model FLOPs for the cell: 6*N_active*tokens for train (fwd+bwd),
    2*N_active*tokens for prefill/decode (fwd only)."""
    _, active = param_count(cfg)
    if shape_spec.kind == "train":
        tokens = shape_spec.batch * shape_spec.seq
        return 6.0 * active * tokens
    if shape_spec.kind == "prefill":
        tokens = shape_spec.batch * shape_spec.seq
        return 2.0 * active * tokens
    tokens = shape_spec.batch  # one new token per row
    return 2.0 * active * tokens
