"""Metric registry: counters, gauges and fixed-bucket histograms.

One registry absorbs every stat dict in the stack under namespaced metric
names (``serve.*``, ``store.*``, ``channel.*``, ``pager.*``, ``train.*``) and
exposes them three ways:

- ``snapshot()``      — one flat dict (histograms summarised with count/sum/
                        min/max and p50/p95/p99), JSON-serialisable;
- ``emit()``          — append the snapshot as one JSONL record to a stream
                        opened with ``stream_to(path)``;
- ``to_prometheus()`` — Prometheus text exposition (histograms as cumulative
                        ``_bucket{le=...}`` series plus ``_sum``/``_count``).

Histograms hold fixed log-spaced buckets (so memory is O(buckets), never
O(observations)) plus a bounded ring of raw samples: tail percentiles are
exact while the ring covers every observation and bucket-interpolated beyond
that — means hide tail latency, which is the whole point of this module.

A registry built with ``enabled=False`` hands out shared null metrics whose
methods are no-ops and snapshots empty: the disabled path is an attribute
check and a no-op call, cheap enough to leave instrumentation permanently in
hot paths (guarded by tests/test_telemetry.py).
"""
from __future__ import annotations

import bisect
import collections
import json
import re
import time

import numpy as np

# log-spaced 1/2.5/5 per decade, 1us .. 100s: wide enough for a decode tick
# (~ms), a prefill chunk (~10ms) and an offloaded fit round (~s) on one scale
DEFAULT_TIME_BUCKETS: tuple[float, ...] = tuple(
    m * 10.0 ** e for e in range(-6, 3) for m in (1.0, 2.5, 5.0))


def percentiles(xs, qs=(50, 95, 99)) -> dict | None:
    """Tail summary of a sample list: count/mean/max plus p50/p95/p99.
    Returns None for an empty sample (callers report 'no data', not zeros)."""
    xs = list(xs)
    if not xs:
        return None
    a = np.asarray(xs, np.float64)
    out = {"count": int(a.size), "mean": float(a.mean()), "max": float(a.max())}
    for q in qs:
        out[f"p{q}"] = float(np.percentile(a, q))
    return out


class Counter:
    """Monotonic count. ``set`` exists for mirroring an external stat dict
    (absorb) — the source is the monotonic authority, not this object."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def set(self, v) -> None:
        self.value = v


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v) -> None:
        self.value = v


class Histogram:
    """Fixed-bucket histogram with a bounded raw-sample ring.

    ``buckets`` are upper bounds (ascending); observations beyond the last
    bound land in the implicit +Inf bucket. Percentiles are exact while the
    ring (``sample_cap`` most recent values) still holds every observation,
    and linearly interpolated from bucket counts beyond that.
    """

    __slots__ = ("buckets", "counts", "count", "sum", "min", "max", "_ring")

    def __init__(self, buckets: tuple[float, ...] = DEFAULT_TIME_BUCKETS,
                 sample_cap: int = 4096):
        self.buckets = tuple(float(b) for b in buckets)
        assert list(self.buckets) == sorted(self.buckets), "buckets ascending"
        self.counts = np.zeros(len(self.buckets) + 1, np.int64)
        self.count = 0
        self.sum = 0.0
        self.min = None
        self.max = None
        self._ring = collections.deque(maxlen=sample_cap)

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.count += 1
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        self._ring.append(v)

    def percentile(self, q: float) -> float | None:
        """q in [0, 100]. Exact over the sample ring when it is complete,
        bucket-interpolated otherwise."""
        if self.count == 0:
            return None
        if self.count <= self._ring.maxlen:
            return float(np.percentile(np.asarray(self._ring, np.float64), q))
        target = (q / 100.0) * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if cum + c >= target:
                lo = self.buckets[i - 1] if i > 0 else 0.0
                hi = self.buckets[i] if i < len(self.buckets) else self.max
                frac = (target - cum) / max(c, 1)
                return float(lo + frac * (hi - lo))
            cum += c
        return float(self.max)

    def summary(self) -> dict:
        if self.count == 0:
            return {"count": 0}
        return {"count": int(self.count), "sum": float(self.sum),
                "mean": float(self.sum / self.count),
                "min": float(self.min), "max": float(self.max),
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99)}


class _NullMetric:
    """Shared no-op stand-in for every metric kind on a disabled registry."""

    __slots__ = ()

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, v) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def percentile(self, q: float):
        return None

    def summary(self) -> dict:
        return {"count": 0}


NULL_METRIC = _NullMetric()


class MetricRegistry:
    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._metrics: dict[str, object] = {}
        self._stream_path: str | None = None

    # -- access / creation -------------------------------------------------
    def _get(self, name: str, kind):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = kind()
        assert isinstance(m, kind), f"{name} already registered as {type(m)}"
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter) if self.enabled else NULL_METRIC

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge) if self.enabled else NULL_METRIC

    def histogram(self, name: str,
                  buckets: tuple[float, ...] = DEFAULT_TIME_BUCKETS
                  ) -> Histogram:
        if not self.enabled:
            return NULL_METRIC
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Histogram(buckets)
        return m

    # -- absorption of legacy stat dicts -----------------------------------
    def absorb(self, namespace: str, stats: dict) -> None:
        """Mirror a component's stat dict under ``namespace.*``: ints become
        counters (set to the source value — the dict stays the authority),
        floats/bools become gauges; nested dicts recurse dotted."""
        if not self.enabled:
            return
        for k, v in stats.items():
            name = f"{namespace}.{k}"
            if isinstance(v, dict):
                self.absorb(name, v)
            elif isinstance(v, bool):
                self.gauge(name).set(int(v))
            elif isinstance(v, (int, np.integer)):
                self.counter(name).set(int(v))
            elif isinstance(v, (float, np.floating)):
                self.gauge(name).set(float(v))
            elif v is None:
                continue
            else:   # strings and other non-numerics have no metric shape
                continue

    # -- export ------------------------------------------------------------
    def snapshot(self) -> dict:
        out: dict = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Histogram):
                out[name] = m.summary()
            else:
                out[name] = m.value
        return out

    def stream_to(self, path: str) -> None:
        self._stream_path = path

    def emit(self, **extra) -> None:
        """Append one JSONL record {ts, **extra, metrics: snapshot()}."""
        if not self.enabled or self._stream_path is None:
            return
        rec = {"ts": time.time(), **extra, "metrics": self.snapshot()}
        with open(self._stream_path, "a") as f:
            f.write(json.dumps(rec) + "\n")

    @staticmethod
    def _prom_name(name: str) -> str:
        return re.sub(r"[^a-zA-Z0-9_]", "_", name)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (one scrape's worth)."""
        lines: list[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            pn = self._prom_name(name)
            if isinstance(m, Counter):
                lines += [f"# TYPE {pn} counter", f"{pn} {m.value}"]
            elif isinstance(m, Gauge):
                lines += [f"# TYPE {pn} gauge", f"{pn} {m.value}"]
            else:
                lines.append(f"# TYPE {pn} histogram")
                cum = 0
                for b, c in zip(m.buckets, m.counts[:-1]):
                    cum += int(c)
                    lines.append(f'{pn}_bucket{{le="{b:g}"}} {cum}')
                lines.append(f'{pn}_bucket{{le="+Inf"}} {m.count}')
                lines.append(f"{pn}_sum {m.sum}")
                lines.append(f"{pn}_count {m.count}")
        return "\n".join(lines) + ("\n" if lines else "")
