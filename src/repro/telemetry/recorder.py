"""Flight recorder: bounded rings of recent structured events + postmortems.

Every instrumented component appends small structured events to a per-key
ring (key = ``(scope, id)``: ``("user", 3)`` for an offload channel,
``("slot", 2)`` for a serve slot, ``("train", 0)`` for the train loop). Rings
are bounded (``capacity`` most recent events), so steady-state cost is O(1)
per event and memory is O(keys x capacity) — black-box style.

When something terminal happens — quarantine, validation rollback, a
``PagerError``, a watchdog straggler — ``dump`` freezes that key's ring into
a *postmortem*: an in-memory record (``recorder.postmortems``) and, when the
recorder has an ``out_dir``, a JSON file::

    postmortem-<scope>-<id>-<n>.json
    {"scope": ..., "key": ..., "reason": ..., "dumped_at": ...,
     "events": [{"t": <unix time>, "kind": ..., ...}, ...]}

so a dead-lettered update or a quarantined user is explainable after the
fact without re-running under fault injection.
"""
from __future__ import annotations

import collections
import json
import os
import re
import time


class FlightRecorder:
    def __init__(self, capacity: int = 64, out_dir: str | None = None,
                 clock=time.time):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.out_dir = out_dir
        self._clock = clock
        self._rings: dict[tuple, collections.deque] = {}
        self.postmortems: list[dict] = []

    # -- event ingestion ---------------------------------------------------
    def record(self, scope: str, key, kind: str, **fields) -> None:
        ring = self._rings.get((scope, key))
        if ring is None:
            ring = self._rings[(scope, key)] = collections.deque(
                maxlen=self.capacity)
        ring.append({"t": self._clock(), "kind": kind, **fields})

    def events(self, scope: str, key) -> list[dict]:
        return list(self._rings.get((scope, key), ()))

    def keys(self) -> list[tuple]:
        return sorted(self._rings, key=repr)

    # -- postmortems -------------------------------------------------------
    def dump(self, scope: str, key, reason: str) -> dict:
        """Freeze a key's ring into a postmortem record (and a JSON file when
        ``out_dir`` is set). Returns the record; ``record["path"]`` carries
        the file path (None when in-memory only)."""
        pm = {"scope": scope, "key": key, "reason": reason,
              "dumped_at": self._clock(),
              "events": self.events(scope, key), "path": None}
        if self.out_dir is not None:
            os.makedirs(self.out_dir, exist_ok=True)
            safe = re.sub(r"[^a-zA-Z0-9_-]", "_", f"{scope}-{key}")
            pm["path"] = os.path.join(
                self.out_dir,
                f"postmortem-{safe}-{len(self.postmortems):03d}.json")
            with open(pm["path"], "w") as f:
                json.dump({k: v for k, v in pm.items() if k != "path"}, f,
                          indent=2, default=str)
                f.write("\n")
        self.postmortems.append(pm)
        return pm
