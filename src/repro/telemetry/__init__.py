"""Unified telemetry for the FTaaS stack: metric registry, span tracing and
the flight recorder behind one facade.

Three pillars (ISSUE 10):

- **Metrics** (`metrics.MetricRegistry`): counters/gauges/fixed-bucket
  histograms under namespaced names (``serve.*``, ``store.*``, ``channel.*``,
  ``pager.*``, ``train.*``) with one ``snapshot()``, a JSONL streamer and a
  Prometheus text exporter. The five legacy stat dicts keep working and are
  absorbed into the registry.
- **Tracing** (`tracing.Tracer`): Chrome-trace-event (Perfetto-loadable)
  spans — per-tick serve spans and per-user offload-round spans carrying the
  channel's seq ids in their args. Read back with
  ``python -m repro.trace_summary``.
- **Flight recorder** (`recorder.FlightRecorder`): bounded per-user/per-slot
  rings of recent events, frozen into postmortem files on quarantine,
  validation rollback, PagerError or a watchdog straggler.

Usage: build one ``Telemetry`` and hand it to the components you want
observed (``ServeEngine(telemetry=tm)``, ``ColaSession(telemetry=tm)``,
``TrainLoop(telemetry=tm)``, ...). Components accept ``telemetry=None``
(the default): the disabled path is one attribute check per site and MUST
stay a no-op — generated tokens are bit-identical telemetry-on vs. off
because telemetry only ever *reads* host-side values and never touches a
jitted computation (guarded by tests/test_telemetry.py).
"""
from __future__ import annotations

import contextlib

from repro.telemetry.metrics import (DEFAULT_TIME_BUCKETS, MetricRegistry,
                                     percentiles)
from repro.telemetry.recorder import FlightRecorder
from repro.telemetry.tracing import Tracer, validate_trace

__all__ = ["Telemetry", "MetricRegistry", "Tracer", "FlightRecorder",
           "validate_trace", "percentiles", "annotate", "NULL_CONTEXT",
           "DEFAULT_TIME_BUCKETS"]

# one shared reusable no-op context: the entire cost of a disabled span
NULL_CONTEXT = contextlib.nullcontext()

# module-global switch for jax-profiler annotations around jitted dispatches
_ANNOTATE = False


def enable_jax_annotations(on: bool) -> None:
    global _ANNOTATE
    _ANNOTATE = bool(on)


def annotate(name: str):
    """Optional ``jax.profiler.TraceAnnotation`` around a jitted hot-path
    dispatch (decode tick, prefill chunk, offloaded fit). Off by default —
    the disabled path returns the shared null context. Enable via
    ``Telemetry(jax_annotations=True)`` when profiling with the jax/TensorBoard
    profiler; the annotation names host dispatch slices in that timeline."""
    if not _ANNOTATE:
        return NULL_CONTEXT
    from jax.profiler import TraceAnnotation
    return TraceAnnotation(name)


class Telemetry:
    """Facade tying the registry, tracer and flight recorder together.

    Parameters
    ----------
    enabled           : master switch. ``Telemetry(enabled=False)`` is
                        indistinguishable from passing ``telemetry=None``.
    trace             : collect Chrome-trace spans (off by default — spans
                        accumulate in memory until ``export_trace``).
    recorder_capacity : events retained per flight-recorder key.
    out_dir           : where postmortem files land (None = in-memory only).
    jax_annotations   : arm ``annotate()`` hooks around jitted dispatches.
    """

    def __init__(self, *, enabled: bool = True, trace: bool = False,
                 recorder_capacity: int = 64, out_dir: str | None = None,
                 jax_annotations: bool = False):
        self.enabled = bool(enabled)
        self.registry = MetricRegistry(enabled=self.enabled)
        self.tracer = Tracer() if (self.enabled and trace) else None
        self.recorder = (FlightRecorder(capacity=recorder_capacity,
                                        out_dir=out_dir)
                         if self.enabled else None)
        if self.enabled and jax_annotations:
            enable_jax_annotations(True)

    def __bool__(self) -> bool:
        return self.enabled

    # -- tracing -----------------------------------------------------------
    def span(self, name: str, cat: str = "serve", tid: int = 0, **args):
        if self.tracer is None:
            return NULL_CONTEXT
        return self.tracer.span(name, cat=cat, tid=tid, **args)

    def name_thread(self, tid: int, name: str) -> None:
        if self.tracer is not None:
            self.tracer.name_thread(tid, name)

    def export_trace(self, path: str) -> str | None:
        return self.tracer.export(path) if self.tracer is not None else None

    # -- flight recorder ---------------------------------------------------
    def record(self, scope: str, key, kind: str, **fields) -> None:
        if self.recorder is not None:
            self.recorder.record(scope, key, kind, **fields)

    def dump(self, scope: str, key, reason: str) -> dict | None:
        if self.recorder is not None:
            return self.recorder.dump(scope, key, reason)
        return None

    # -- metrics -----------------------------------------------------------
    def snapshot(self) -> dict:
        return self.registry.snapshot()
