"""Span tracing with Chrome-trace-event JSON export.

``Tracer.span(...)`` is a context manager that appends one complete ("ph":
"X") trace event per exit — name, category, microsecond timestamp + duration
relative to the tracer's epoch, and free-form ``args`` (user ids, channel seq
ids, tick numbers). The exported document::

    {"traceEvents": [...], "displayTimeUnit": "ms"}

loads directly in Perfetto / chrome://tracing. Events on one ``tid`` lane
nest by construction (a child span enters after and exits before its parent),
which ``validate_trace`` checks — the tier-1 schema test and the
``repro.trace_summary`` reader both run it.

Lanes (tid) are a convention, not a mechanism: the serve engine emits on the
"serve" lane, the train loop + offload channels on "offload" lanes. Metadata
("M") events name them for the viewer.
"""
from __future__ import annotations

import contextlib
import json
import os
import time


class Tracer:
    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self._t0 = clock()
        self._pid = os.getpid()
        self.events: list[dict] = []
        self._named_tids: set[int] = set()

    # -- emission ----------------------------------------------------------
    def now_us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    def name_thread(self, tid: int, name: str) -> None:
        """Label a tid lane in the viewer (idempotent)."""
        if tid in self._named_tids:
            return
        self._named_tids.add(tid)
        self.events.append({"name": "thread_name", "ph": "M", "pid": self._pid,
                            "tid": tid, "args": {"name": name}})

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "serve", tid: int = 0, **args):
        t0 = self.now_us()
        try:
            yield
        finally:
            t1 = self.now_us()
            ev = {"name": name, "cat": cat, "ph": "X", "pid": self._pid,
                  "tid": tid, "ts": t0, "dur": t1 - t0}
            if args:
                ev["args"] = args
            self.events.append(ev)

    def instant(self, name: str, cat: str = "serve", tid: int = 0, **args):
        ev = {"name": name, "cat": cat, "ph": "i", "pid": self._pid,
              "tid": tid, "ts": self.now_us(), "s": "t"}
        if args:
            ev["args"] = args
        self.events.append(ev)

    # -- export ------------------------------------------------------------
    def to_doc(self) -> dict:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_doc(), f)
            f.write("\n")
        return path


# ---------------------------------------------------------------------------
# trace-event schema validation (tier-1 test + trace_summary both run this)
# ---------------------------------------------------------------------------

_REQUIRED = ("name", "ph", "pid", "tid")


def validate_trace(doc: dict) -> list[str]:
    """Validate a Chrome-trace-event document. Returns a list of problems
    (empty = valid): well-formed container, required event fields, and — for
    complete events sharing a (pid, tid) lane — proper span nesting: a span
    that starts inside another must also end inside it."""
    problems: list[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["document is not a {'traceEvents': [...]} object"]
    events = doc["traceEvents"]
    if not isinstance(events, list) or not events:
        return ["traceEvents is empty or not a list"]
    lanes: dict[tuple, list[tuple[float, float, str]]] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i} is not an object")
            continue
        missing = [k for k in _REQUIRED if k not in ev]
        if missing:
            problems.append(f"event {i} missing fields {missing}")
            continue
        if ev["ph"] == "M":
            continue                       # metadata carries no timestamp
        if "ts" not in ev or not isinstance(ev["ts"], (int, float)):
            problems.append(f"event {i} ({ev['name']}) has no numeric ts")
            continue
        if ev["ph"] == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i} ({ev['name']}) has bad dur {dur!r}")
                continue
            lanes.setdefault((ev["pid"], ev["tid"]), []).append(
                (float(ev["ts"]), float(ev["ts"]) + float(dur), ev["name"]))
    if not lanes:
        problems.append("no complete ('X') span events in trace")
    eps = 1e-3   # us; guards float round-trip through JSON
    for lane, spans in lanes.items():
        # sort by start asc, end desc: parents come before their children
        spans.sort(key=lambda s: (s[0], -s[1]))
        stack: list[tuple[float, float, str]] = []
        for ts, end, name in spans:
            while stack and ts >= stack[-1][1] - eps:
                stack.pop()
            if stack and end > stack[-1][1] + eps:
                problems.append(
                    f"lane {lane}: span '{name}' [{ts:.1f}, {end:.1f}] "
                    f"overlaps parent '{stack[-1][2]}' ending {stack[-1][1]:.1f}")
            stack.append((ts, end, name))
    return problems
