"""Data pipeline: deterministic, restartable, host-sharded.

Every source exposes ``batch_at(step) -> batch dict`` as a pure function of the
step index (and seed), so a restarted job resumes mid-epoch with zero state
beyond the step counter — the fault-tolerance contract the train loop relies on.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class SyntheticLM:
    """Seeded synthetic LM stream with a learnable structure: a fixed random
    bigram transition table generates the tokens, so models can actually reduce
    loss (needed by the learning-curve/equivalence benchmarks)."""
    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0
    users: int = 1
    host_id: int = 0
    n_hosts: int = 1

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.cfg.vocab_size
        # sparse-ish bigram table: each token has 8 likely successors
        self._succ = rng.integers(0, v, size=(v, 8), dtype=np.int32)

    def _gen_tokens(self, rng: np.random.Generator, b: int, s: int) -> np.ndarray:
        v = self.cfg.vocab_size
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, v, size=b)
        choice = rng.integers(0, 8, size=(b, s))
        noise = rng.random((b, s)) < 0.1
        rand = rng.integers(0, v, size=(b, s), dtype=np.int32)
        for t in range(s):
            nxt = self._succ[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        return toks

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 7919 + self.host_id)
        b = self.batch // self.n_hosts
        if self.cfg.n_codebooks:
            toks = np.stack([self._gen_tokens(rng, b, self.seq)
                             for _ in range(self.cfg.n_codebooks)], axis=-1)
            batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        elif self.cfg.embed_input:
            emb = rng.standard_normal(
                (b, self.seq, self.cfg.d_model)).astype(np.float32)
            labels = rng.integers(0, self.cfg.vocab_size,
                                  size=(b, self.seq), dtype=np.int32)
            batch = {"embeds": emb, "labels": labels}
        else:
            toks = self._gen_tokens(rng, b, self.seq)
            batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.users > 1:
            batch["user_id"] = rng.integers(0, self.users, size=(b,),
                                            dtype=np.int32)
        return batch


class ByteCorpus:
    """Byte-level tokenized corpus from a text file (vocab 256 + pad),
    deterministic window sampling by step."""

    def __init__(self, path: str, batch: int, seq: int, seed: int = 0):
        with open(path, "rb") as f:
            self.data = np.frombuffer(f.read(), dtype=np.uint8).astype(np.int32)
        assert len(self.data) > seq + 1, "corpus too small"
        self.batch, self.seq, self.seed = batch, seq, seed

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(self.seed * 1_000_003 + step)
        starts = rng.integers(0, len(self.data) - self.seq - 1, size=self.batch)
        idx = starts[:, None] + np.arange(self.seq + 1)[None, :]
        toks = self.data[idx]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def shard_batch(batch: dict, mesh=None, shardings=None) -> dict:
    """Place a host batch onto devices (with shardings when given)."""
    if shardings is None:
        return jax.tree.map(jax.numpy.asarray, batch)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), batch,
        {k: shardings[k] for k in batch})
