"""Shared utilities: pytree helpers, rng, dtype handling, shape math."""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Iterable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


# ---------------------------------------------------------------------------
# dtypes
# ---------------------------------------------------------------------------

def canonical_dtype(name: str | jnp.dtype) -> jnp.dtype:
    if isinstance(name, str):
        return jnp.dtype({
            "bf16": jnp.bfloat16,
            "bfloat16": jnp.bfloat16,
            "f32": jnp.float32,
            "float32": jnp.float32,
            "f16": jnp.float16,
        }[name])
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# rng helpers
# ---------------------------------------------------------------------------

def split_like(key: jax.Array, names: Iterable[str]) -> dict[str, jax.Array]:
    names = list(names)
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


def fold_in_str(key: jax.Array, s: str) -> jax.Array:
    h = np.uint32(abs(hash(s)) % (2**31 - 1))
    return jax.random.fold_in(key, h)


# ---------------------------------------------------------------------------
# pytree helpers
# ---------------------------------------------------------------------------

def tree_size_bytes(tree: PyTree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_count(tree: PyTree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_scale(a: PyTree, s) -> PyTree:
    return jax.tree.map(lambda x: x * s, a)


def tree_cast(tree: PyTree, dtype) -> PyTree:
    dtype = canonical_dtype(dtype)
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def tree_allclose(a: PyTree, b: PyTree, *, rtol=1e-5, atol=1e-6) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(la) != len(lb):
        return False
    return all(np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol)
               for x, y in zip(la, lb))


def flatten_dict(d: Mapping, prefix: str = "", sep: str = ".") -> dict[str, Any]:
    out: dict[str, Any] = {}
    for k, v in d.items():
        kk = f"{prefix}{sep}{k}" if prefix else str(k)
        if isinstance(v, Mapping):
            out.update(flatten_dict(v, kk, sep))
        else:
            out[kk] = v
    return out


def unflatten_dict(d: Mapping[str, Any], sep: str = ".") -> dict:
    out: dict = {}
    for k, v in d.items():
        parts = k.split(sep)
        cur = out
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return out


# ---------------------------------------------------------------------------
# shape math
# ---------------------------------------------------------------------------

def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def human_bytes(n: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(n) < 1024:
            return f"{n:.2f} {unit}"
        n /= 1024
    return f"{n:.2f} EB"


def human_count(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000:
            return f"{n:.2f}{unit}" if unit else f"{n:.0f}"
        n /= 1000
    return f"{n:.2f}Q"


def asdict_shallow(dc) -> dict:
    return {f.name: getattr(dc, f.name) for f in dataclasses.fields(dc)}
