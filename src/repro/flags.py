"""Trace-time flags (module-global, context-managed).

``unroll_scans`` / ``dense_sdpa`` exist for the dry-run *cost pass*: XLA's
cost_analysis counts a while-loop body ONCE regardless of trip count (verified
by calibration), so for exact HLO_FLOPs/bytes/collective totals the dry-run
compiles a second variant with every scan unrolled. The *memory pass* keeps
scans rolled (the realistic execution schedule for memory_analysis).
"""
from __future__ import annotations

import contextlib

_FLAGS = {
    "unroll_scans": False,   # unroll layer/chunk/loss scans (cost accounting)
    "dense_sdpa": False,     # use the dense O(S^2) sdpa (loop-free costs)
}


def get(name: str) -> bool:
    return _FLAGS[name]


def scan_unroll() -> bool | int:
    """Value to pass as lax.scan(unroll=...)."""
    return True if _FLAGS["unroll_scans"] else 1


@contextlib.contextmanager
def override(**kw):
    old = {k: _FLAGS[k] for k in kw}
    _FLAGS.update(kw)
    try:
        yield
    finally:
        _FLAGS.update(old)
