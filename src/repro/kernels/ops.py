"""Public jit'd entry points for the kernel layer.

Backend selection: ``set_backend("ref" | "pallas" | "pallas_interpret")``.
- "ref"              : pure-jnp oracles (default on CPU; what this container runs).
- "pallas"           : compiled Pallas TPU kernels (the deployment target).
- "pallas_interpret" : Pallas kernels executed in interpret mode (CPU-correctness).

Models call these wrappers; nothing below the ops layer knows about the backend.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import ref

Array = jax.Array
_BACKEND: str = "ref"


def set_backend(name: Literal["ref", "pallas", "pallas_interpret"]) -> None:
    global _BACKEND
    assert name in ("ref", "pallas", "pallas_interpret"), name
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


def _interpret() -> bool:
    return _BACKEND == "pallas_interpret"


# ---------------------------------------------------------------------------
# sdpa / flash attention
# ---------------------------------------------------------------------------

def sdpa(q: Array, k: Array, v: Array, *, q_positions: Array, kv_positions: Array,
         causal: bool = True, window: int | None = None,
         softcap: float | None = None, scale: float | None = None) -> Array:
    """Attention entry point used by the model zoo (see ref.sdpa for semantics)."""
    if _BACKEND != "ref":
        from repro.kernels import flash_attention as fa
        if fa.supported(q, k, v, q_positions=q_positions, causal=causal):
            return fa.flash_attention(
                q, k, v, q_positions=q_positions, kv_positions=kv_positions,
                causal=causal, window=window, softcap=softcap, scale=scale,
                interpret=_interpret())
    return ref.sdpa(q, k, v, q_positions=q_positions, kv_positions=kv_positions,
                    causal=causal, window=window, softcap=softcap, scale=scale)


def sdpa_decode(q: Array, k_cache: Array, v_cache: Array, positions: Array, *,
                live: Array | None = None, window: int | None = None,
                softcap: float | None = None, scale: float | None = None) -> Array:
    """Incremental attention against a dense slot KV cache (serving hot path):
    per-row positions, per-slot live mask; Sq == 1 is the decode tick, Sq > 1 a
    prefill chunk. Routes the single-query case to the fused flash-decode
    kernel off-CPU; see ref.sdpa_decode for semantics."""
    if _BACKEND != "ref":
        from repro.kernels import decode_attention as da
        if da.supported(q, k_cache, v_cache):
            return da.decode_attention(q, k_cache, v_cache, positions,
                                       live=live, window=window,
                                       softcap=softcap, scale=scale,
                                       interpret=_interpret())
    return ref.sdpa_decode(q, k_cache, v_cache, positions, live=live,
                           window=window, softcap=softcap, scale=scale)


def sdpa_decode_paged(q: Array, k_pool: Array, v_pool: Array, positions: Array,
                      block_table: Array, *, live: Array | None = None,
                      window: int | None = None, softcap: float | None = None,
                      scale: float | None = None) -> Array:
    """Paged-KV incremental attention: the cache is a shared block pool
    (n_blocks, block, K, Dh) addressed through a per-slot ``block_table``
    (B, max_blocks). The fused kernel scalar-prefetches the table and reads
    pool blocks directly (no gather); the ref path gathers a dense per-slot
    view. See ref.sdpa_decode_paged for semantics."""
    if _BACKEND != "ref":
        from repro.kernels import decode_attention as da
        if da.supported_paged(q, k_pool, v_pool, block_table):
            return da.decode_attention_paged(q, k_pool, v_pool, positions,
                                             block_table, live=live,
                                             window=window, softcap=softcap,
                                             scale=scale,
                                             interpret=_interpret())
    return ref.sdpa_decode_paged(q, k_pool, v_pool, positions, block_table,
                                 live=live, window=window, softcap=softcap,
                                 scale=scale)


def sdpa_decode_ring(q: Array, k_ring: Array, v_ring: Array, positions: Array,
                     *, live: Array | None = None, window: int | None = None,
                     softcap: float | None = None,
                     scale: float | None = None) -> Array:
    """Rolling-window (ring) incremental attention for local-window layers
    under the paged layout. The ring is window-sized, so there is no long
    cache to stream — the position-ordered gather + dense math in
    ref.sdpa_decode_ring is the implementation on every backend."""
    return ref.sdpa_decode_ring(q, k_ring, v_ring, positions, live=live,
                                window=window, softcap=softcap, scale=scale)


# ---------------------------------------------------------------------------
# cola_fit
# ---------------------------------------------------------------------------

def cola_fit_lowrank(x: Array, grad_h: Array, A: Array, B: Array,
                     scale: float = 1.0) -> tuple[Array, Array]:
    if _BACKEND != "ref":
        from repro.kernels import cola_fit as ck
        if ck.supported(x, grad_h, A, B):
            return ck.cola_fit_lowrank(x, grad_h, A, B, scale=scale,
                                       interpret=_interpret())
    return ref.cola_fit_lowrank(x, grad_h, A, B, scale=scale)


# ---------------------------------------------------------------------------
# multi_lora
# ---------------------------------------------------------------------------

def multi_lora(x: Array, A: Array, B: Array, idx: Array, scale: float = 1.0) -> Array:
    if _BACKEND != "ref":
        from repro.kernels import multi_lora as ml
        # decode-shaped dispatch (BGMV idiom): when the bank is larger than
        # the token batch, compact to the resident adapter set first so the
        # kernel's user grid scales with min(U, T) instead of U.
        grouped = A.shape[0] > x.shape[0]
        fn = ml.multi_lora_grouped if grouped else ml.multi_lora
        if ml.supported(x, A, B, idx, grouped=grouped):
            return fn(x, A, B, idx, scale=scale, interpret=_interpret())
        # prefill-shaped dispatch: a (J, P) prompt batch flattens to J*P tokens,
        # which rarely aligns with the kernel's token blocking. Pad with
        # no-user rows (idx == -1 contributes zeros) and slice back.
        padded = ml.pad_tokens(x, idx)
        if padded is not None and ml.supported(padded[0], A, B, padded[1],
                                               grouped=grouped):
            y = fn(padded[0], A, B, padded[1], scale=scale,
                   interpret=_interpret())
            return y[:x.shape[0]]
    return ref.multi_lora(x, A, B, idx, scale=scale)


def multi_lora_q8(x: Array, A_q: Array, A_scale: Array, B_q: Array,
                  B_scale: Array, idx: Array, scale: float = 1.0) -> Array:
    """int8-stored bank apply with fused dequant-on-load (see ref.multi_lora_q8
    for the oracle semantics; the serve path never materialises a f32 bank)."""
    if _BACKEND != "ref":
        from repro.kernels import multi_lora as ml
        if ml.supported(x, A_q, B_q, idx):
            return ml.multi_lora_q8(x, A_q, A_scale, B_q, B_scale, idx,
                                    scale=scale, interpret=_interpret())
        padded = ml.pad_tokens(x, idx)
        if padded is not None and ml.supported(padded[0], A_q, B_q, padded[1]):
            y = ml.multi_lora_q8(padded[0], A_q, A_scale, B_q, B_scale,
                                 padded[1], scale=scale, interpret=_interpret())
            return y[:x.shape[0]]
    return ref.multi_lora_q8(x, A_q, A_scale, B_q, B_scale, idx, scale=scale)


# ---------------------------------------------------------------------------
# ssd (mamba2) — chunked jnp implementation with optional Pallas inner kernel
# ---------------------------------------------------------------------------

def ssd(x: Array, dt: Array, a: Array, B: Array, C: Array, D: Array,
        init_state: Array | None = None, *, chunk: int = 128) -> tuple[Array, Array]:
    """Chunked SSD scan (linear-time). Falls back to ref on tiny sequences."""
    S = x.shape[1]
    if S <= chunk:
        return ref.ssd(x, dt, a, B, C, D, init_state)
    from repro.kernels import ssd_scan
    return ssd_scan.ssd_chunked(x, dt, a, B, C, D, init_state, chunk=chunk,
                                backend=_BACKEND)


def ssd_decode_step(x: Array, dt: Array, a: Array, B: Array, C: Array, D: Array,
                    state: Array) -> tuple[Array, Array]:
    return ref.ssd_decode_step(x, dt, a, B, C, D, state)
