"""ColA fit kernel (Pallas): the offloaded Gradient-Learning step for the
low-rank family, fused so the (T, r) intermediate never round-trips to HBM.

  dB = (x @ A)^T @ grad_h        dA = x^T @ (grad_h @ B^T)

The token axis T (= I * B * S rows after interval buffering) is the streaming
grid dimension; dA/dB accumulate in VMEM scratch. This is ColA's own compute
hot-spot: at interval I the offload device processes I*B*S rows per tap.

Oracle: repro.kernels.ref.cola_fit_lowrank.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def supported(x, grad_h, A, B) -> bool:
    T, d_in = x.shape
    d_out = grad_h.shape[-1]
    r = A.shape[-1]
    if d_in > 8192 or d_out > 8192 or r > 256:
        return False          # VMEM budget for the unblocked feature dims
    return T % _block_t(T) == 0


def _block_t(t: int) -> int:
    for b in (512, 256, 128, 64, 32, 16, 8):
        if t % b == 0 and b <= t:
            return b
    return t


def _kernel(x_ref, g_ref, a_ref, b_ref, da_ref, db_ref, da_acc, db_acc, *,
            scale):
    ti = pl.program_id(0)

    @pl.when(ti == 0)
    def _init():
        da_acc[...] = jnp.zeros_like(da_acc)
        db_acc[...] = jnp.zeros_like(db_acc)

    x = x_ref[...].astype(jnp.float32)       # (Bt, d_in)
    g = g_ref[...].astype(jnp.float32)       # (Bt, d_out)
    a = a_ref[...].astype(jnp.float32)       # (d_in, r)
    b = b_ref[...].astype(jnp.float32)       # (r, d_out)

    xa = jax.lax.dot_general(x, a, (((1,), (0,)), ((), ())))       # (Bt, r)
    db_acc[...] += jax.lax.dot_general(xa, g, (((0,), (0,)), ((), ())))
    gb = jax.lax.dot_general(g, b, (((1,), (1,)), ((), ())))       # (Bt, r)
    da_acc[...] += jax.lax.dot_general(x, gb, (((0,), (0,)), ((), ())))

    @pl.when(ti == pl.num_programs(0) - 1)
    def _final():
        da_ref[...] = (scale * da_acc[...]).astype(da_ref.dtype)
        db_ref[...] = (scale * db_acc[...]).astype(db_ref.dtype)


def cola_fit_lowrank(x: Array, grad_h: Array, A: Array, B: Array, *,
                     scale: float = 1.0, interpret: bool = False
                     ) -> tuple[Array, Array]:
    T, d_in = x.shape
    d_out = grad_h.shape[-1]
    r = A.shape[-1]
    bt = _block_t(T)
    grid = (T // bt,)
    with jax.named_scope("cola_fit_lowrank"):
        dA, dB = _pallas_fit(x, grad_h, A, B, scale=scale, bt=bt, grid=grid,
                             d_in=d_in, d_out=d_out, r=r, interpret=interpret)
    return dA, dB


def _pallas_fit(x, grad_h, A, B, *, scale, bt, grid, d_in, d_out, r,
                interpret):
    dA, dB = pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d_in), lambda t: (t, 0)),
            pl.BlockSpec((bt, d_out), lambda t: (t, 0)),
            pl.BlockSpec((d_in, r), lambda t: (0, 0)),
            pl.BlockSpec((r, d_out), lambda t: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((d_in, r), lambda t: (0, 0)),
            pl.BlockSpec((r, d_out), lambda t: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d_in, r), jnp.float32),
            jax.ShapeDtypeStruct((r, d_out), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((d_in, r), jnp.float32),
            pltpu.VMEM((r, d_out), jnp.float32),
        ],
        interpret=interpret,
    )(x, grad_h, A, B)
    return dA, dB
