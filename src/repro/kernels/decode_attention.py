"""Fused single-query flash-attention decode kernel (Pallas, TPU target).

The serving decode hot path: every live slot attends one new query against its
KV cache row. The kernel streams the cache in (block_k x d_head) VMEM tiles
with the online-softmax running stats in scratch — the decode analogue of the
prefill flash kernel — with three decode-specific twists:

- **GQA layout**: the G query heads of one KV group form the *rows* of the q
  tile ((G, Dh) per grid step), so each KV tile is read once per group and the
  (G, block_k) score tile is real MXU work even though Sq == 1.
- **Per-slot positions via scalar prefetch**: each row's current position (and
  its ``live`` bit) arrives in SMEM before the grid runs; KV blocks entirely
  above the position (or entirely below the local-window floor) are skipped
  with ``pl.when`` — continuous batching means rows at wildly different
  positions share one launch.
- **Live-slot semantics**: dead/padding slots produce exact zeros (not
  attention over a stale cache), matching ``ref.sdpa_decode``.

Oracle: ``repro.kernels.ref.sdpa_decode``. Tests sweep GQA group counts,
window/softcap variants and live-mask patterns in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
NEG_INF = -1e30


def supported(q, k_cache, v_cache) -> bool:
    B, Sq, H, Dh = q.shape
    _, Sk, K, _ = k_cache.shape
    if Sq != 1 or Dh not in (64, 128, 256):
        return False
    if H % K != 0:
        return False
    return Sk % _block_k(Sk) == 0


def supported_paged(q, k_pool, v_pool, block_table) -> bool:
    B, Sq, H, Dh = q.shape
    _, bs, K, _ = k_pool.shape
    if Sq != 1 or Dh not in (64, 128, 256):
        return False
    if H % K != 0:
        return False
    return bs % 8 == 0


def _block_k(sk: int) -> int:
    for b in (512, 256, 128, 64, 32, 16, 8):
        if sk % b == 0 and b <= sk:
            return b
    return sk


def _kernel(pos_ref, live_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
            l_ref, *, scale, window, softcap, block_k, n_groups):
    bi = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[bi]
    start = ki * block_k
    # block intersects the valid kv range [max(0, pos - window + 1), pos]?
    in_range = start <= pos
    if window is not None and window > 0:
        in_range = in_range & (start + block_k > pos - window + 1)

    @pl.when(in_range)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (G, Dh)
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # (Bk, Dh)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if softcap is not None and softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        kpos = start + jax.lax.broadcasted_iota(
            jnp.int32, (n_groups, block_k), 1)
        mask = kpos <= pos
        if window is not None and window > 0:
            mask = mask & (kpos > pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _final():
        l = l_ref[...]
        safe_l = jnp.where(l > 0, l, 1.0)
        out = acc_ref[...] / safe_l[:, None]
        out = out * (live_ref[bi] > 0).astype(jnp.float32)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     positions: Array, *, live: Array | None = None,
                     window: int | None = None, softcap: float | None = None,
                     scale: float | None = None,
                     interpret: bool = False) -> Array:
    """q: (B, 1, H, Dh); caches: (B, Smax, K, Dh); positions: (B,) int32;
    live: (B,) bool or None (all live). Returns (B, 1, H, Dh)."""
    B, Sq, H, Dh = q.shape
    _, Sk, K, _ = k_cache.shape
    G = H // K
    if scale is None:
        scale = Dh ** -0.5
    bk = _block_k(Sk)
    if live is None:
        live = jnp.ones((B,), bool)
    qg = q.reshape(B, K, G, Dh)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, Sk // bk),
        in_specs=[
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, ki, pos, live: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, Dh), lambda b, h, ki, pos, live: (b, ki, h, 0)),
            pl.BlockSpec((1, bk, 1, Dh), lambda b, h, ki, pos, live: (b, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, G, Dh), lambda b, h, ki, pos, live: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, Dh), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    o = pl.pallas_call(
        functools.partial(_kernel, scale=scale, window=window, softcap=softcap,
                          block_k=bk, n_groups=G),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, Dh), q.dtype),
        interpret=interpret,
    )(positions.astype(jnp.int32), live.astype(jnp.int32), qg, k_cache, v_cache)
    return o.reshape(B, 1, H, Dh)


def _kernel_paged(pos_ref, live_ref, table_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, scale, window, softcap, block_k,
                  n_groups):
    # Identical online-softmax math to the dense kernel: grid axis 2 walks the
    # row's block *table* slots in position order, so ki * block_k is still the
    # absolute kv position of the tile — only the BlockSpec index maps differ
    # (the tile is fetched from pool row table[b, ki] instead of (b, ki)).
    _kernel(pos_ref, live_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
            l_ref, scale=scale, window=window, softcap=softcap,
            block_k=block_k, n_groups=n_groups)


def decode_attention_paged(q: Array, k_pool: Array, v_pool: Array,
                           positions: Array, block_table: Array, *,
                           live: Array | None = None,
                           window: int | None = None,
                           softcap: float | None = None,
                           scale: float | None = None,
                           interpret: bool = False) -> Array:
    """Fused single-query decode attention over a paged KV pool.

    q: (B, 1, H, Dh); pools: (n_blocks, block, K, Dh) shared across slots;
    block_table: (B, max_blocks) int32 mapping (slot, position // block) to a
    pool block id. The table rides in as a third scalar-prefetch operand (next
    to positions/live): the k/v BlockSpec index maps dereference
    ``table[b, ki]`` so each grid step DMAs its tile straight out of the pool
    — no gathered dense copy of the cache ever exists. Blocks past a row's
    position (including unallocated table entries, which point at block 0) are
    skipped by the same ``pl.when`` position test as the dense kernel.
    Oracle: ``ref.sdpa_decode_paged``.
    """
    B, Sq, H, Dh = q.shape
    _, bs, K, _ = k_pool.shape
    nb = block_table.shape[1]
    G = H // K
    if scale is None:
        scale = Dh ** -0.5
    if live is None:
        live = jnp.ones((B,), bool)
    qg = q.reshape(B, K, G, Dh)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, K, nb),
        in_specs=[
            pl.BlockSpec((1, 1, G, Dh),
                         lambda b, h, ki, pos, live, tbl: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, Dh),
                         lambda b, h, ki, pos, live, tbl: (tbl[b, ki], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, Dh),
                         lambda b, h, ki, pos, live, tbl: (tbl[b, ki], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, G, Dh), lambda b, h, ki, pos, live, tbl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, Dh), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    o = pl.pallas_call(
        functools.partial(_kernel_paged, scale=scale, window=window,
                          softcap=softcap, block_k=bs, n_groups=G),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, Dh), q.dtype),
        interpret=interpret,
    )(positions.astype(jnp.int32), live.astype(jnp.int32),
      block_table.astype(jnp.int32), qg, k_pool, v_pool)
    return o.reshape(B, 1, H, Dh)
