"""Multi-LoRA kernel (Pallas): per-token adapter-indexed low-rank apply — the
FTaaS serving hot-spot (K users' adapters inside one decode batch; the BGMV
problem from Punica/S-LoRA, adapted to TPU).

TPU adaptation: instead of CUDA's per-warp gather of adapter weights, the grid
iterates (token-block x user); each user's (A_u, B_u) tile is a clean VMEM
block (index_map on the user axis), the token block computes the full low-rank
product on the MXU and masks rows that do not belong to user u before
accumulating. For K ~ tens of users this trades U-fold MXU passes (cheap,
r << d) for zero irregular memory access (expensive on TPU).

Oracle: repro.kernels.ref.multi_lora.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def supported(x, A, B, idx, *, grouped: bool = False) -> bool:
    T, d_in = x.shape
    U, _, r = A.shape
    d_out = B.shape[-1]
    # grouped dispatch compacts the bank to the resident set, so the kernel's
    # user grid is min(U, T) regardless of bank size.
    eff_users = min(U, T) if grouped else U
    if d_in > 8192 or d_out > 8192 or r > 256 or eff_users > 64:
        return False
    return T % _block_t(T) == 0 and _block_t(T) <= 256


def _block_t(t: int) -> int:
    for b in (256, 128, 64, 32, 16, 8):
        if t % b == 0 and b <= t:
            return b
    return t


PAD_ALIGN = 128


def pad_tokens(x: Array, idx: Array, align: int = PAD_ALIGN):
    """Pad the token axis to a kernel-friendly multiple (prefill batches are
    J*P tokens and rarely align). Padding rows carry user id -1, which matches
    no user block in the kernel mask and therefore contributes zeros; callers
    slice the output back to the original T. Returns None when already aligned.
    """
    from repro.utils import round_up
    T = x.shape[0]
    t2 = round_up(T, align)
    if t2 == T:
        return None
    xp = jnp.pad(x, ((0, t2 - T), (0, 0)))
    ip = jnp.pad(idx.astype(jnp.int32), (0, t2 - T), constant_values=-1)
    return xp, ip


def _kernel(x_ref, a_ref, b_ref, idx_ref, y_ref, acc_ref, *, scale, block_t):
    ui = pl.program_id(1)

    @pl.when(ui == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)          # (Bt, d_in)
    a = a_ref[0].astype(jnp.float32)            # (d_in, r)
    b = b_ref[0].astype(jnp.float32)            # (r, d_out)
    idx = idx_ref[...]                          # (Bt,)

    xa = jax.lax.dot_general(x, a, (((1,), (0,)), ((), ())))
    y = jax.lax.dot_general(xa, b, (((1,), (0,)), ((), ())))
    m = (idx == ui).astype(jnp.float32)[:, None]
    acc_ref[...] += y * m

    @pl.when(ui == pl.num_programs(1) - 1)
    def _final():
        y_ref[...] = (scale * acc_ref[...]).astype(y_ref.dtype)


def multi_lora(x: Array, A: Array, B: Array, idx: Array, *, scale: float = 1.0,
               interpret: bool = False) -> Array:
    T, d_in = x.shape
    U, _, r = A.shape
    d_out = B.shape[-1]
    bt = _block_t(T)
    y = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_t=bt),
        grid=(T // bt, U),
        in_specs=[
            pl.BlockSpec((bt, d_in), lambda t, u: (t, 0)),
            pl.BlockSpec((1, d_in, r), lambda t, u: (u, 0, 0)),
            pl.BlockSpec((1, r, d_out), lambda t, u: (u, 0, 0)),
            pl.BlockSpec((bt,), lambda t, u: (t,)),
        ],
        out_specs=pl.BlockSpec((bt, d_out), lambda t, u: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((T, d_out), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, d_out), jnp.float32)],
        interpret=interpret,
    )(x, A, B, idx.astype(jnp.int32))
    return y


# ---------------------------------------------------------------------------
# grouped decode dispatch (Punica/S-LoRA BGMV idiom)
# ---------------------------------------------------------------------------

def compact_resident(idx: Array, n_users: int, max_groups: int | None = None
                     ) -> tuple[Array, Array]:
    """Compact a decode batch's adapter ids to its *resident set*.

    A decode batch of T token rows references at most min(U, T) distinct
    adapters, while the kernel's user grid (and the dense-over-users cost)
    scales with the bank size U. Sort the ids, mark the distinct ones, and
    remap every row into the compacted id space — the kernel then iterates one
    grouped matmul per *resident* (A, B) pair instead of per bank entry.

    Returns (resident_ids (G,), remapped_idx (T,)): ``resident_ids`` is the
    sorted distinct ids padded with ``n_users``; rows with idx < 0 (padding)
    stay -1 in ``remapped_idx``.
    """
    T = idx.shape[0]
    G = min(n_users, T) if max_groups is None else max_groups
    idx = idx.astype(jnp.int32)
    s = jnp.sort(idx)
    first = jnp.concatenate([jnp.ones((1,), bool), s[1:] != s[:-1]])
    distinct = first & (s >= 0)
    resident = jnp.sort(jnp.where(distinct, s, n_users))[:G]
    remapped = jnp.searchsorted(resident, idx).astype(jnp.int32)
    remapped = jnp.where(idx < 0, -1, remapped)
    return resident, remapped


def multi_lora_grouped(x: Array, A: Array, B: Array, idx: Array, *,
                       scale: float = 1.0, interpret: bool = False) -> Array:
    """Grouped-GEMM decode dispatch: compact the bank to the resident adapter
    set before launching the kernel, so cost scales with min(U, T) rather than
    U. When the bank holds a single adapter (U == 1) the compaction is skipped
    entirely — one grouped matmul pair, rows with idx != 0 masked in-kernel."""
    U = A.shape[0]
    if U == 1:
        return multi_lora(x, A, B, idx, scale=scale, interpret=interpret)
    resident, remapped = compact_resident(idx, U)
    safe = jnp.clip(resident, 0, U - 1)        # pad entries gather arbitrarily;
    A_c, B_c = A[safe], B[safe]                # no row maps to them
    return multi_lora(x, A_c, B_c, remapped, scale=scale, interpret=interpret)


# ---------------------------------------------------------------------------
# int8-stored banks: fused dequant-on-load
# ---------------------------------------------------------------------------

def quant_rows(w: Array) -> tuple[Array, Array]:
    """Per-row (last-dim) symmetric int8 quantisation of an adapter leaf.
    Matches the offload channel's transfer compression (core/offload.py)."""
    wf = w.astype(jnp.float32)
    scale = jnp.max(jnp.abs(wf), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequant_rows(q: Array, scale: Array, dtype=jnp.float32) -> Array:
    """Inverse of ``quant_rows`` — the host-side decode used when an
    int8-stored bank entry must be read back as f32 (similarity vectors,
    cluster merging, tests). The serving path never calls this: kernels
    dequantise on tile load."""
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def _q8_kernel(x_ref, aq_ref, as_ref, bq_ref, bs_ref, idx_ref, y_ref, acc_ref,
               *, scale, block_t):
    ui = pl.program_id(1)

    @pl.when(ui == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)                        # (Bt, d_in)
    # dequant-on-load: the int8 tiles + row scales are what crosses HBM->VMEM;
    # the f32 view exists only as this block's VMEM working set.
    a = aq_ref[0].astype(jnp.float32) * as_ref[0].astype(jnp.float32)
    b = bq_ref[0].astype(jnp.float32) * bs_ref[0].astype(jnp.float32)
    idx = idx_ref[...]

    xa = jax.lax.dot_general(x, a, (((1,), (0,)), ((), ())))
    y = jax.lax.dot_general(xa, b, (((1,), (0,)), ((), ())))
    m = (idx == ui).astype(jnp.float32)[:, None]
    acc_ref[...] += y * m

    @pl.when(ui == pl.num_programs(1) - 1)
    def _final():
        y_ref[...] = (scale * acc_ref[...]).astype(y_ref.dtype)


def multi_lora_q8(x: Array, A_q: Array, A_scale: Array, B_q: Array,
                  B_scale: Array, idx: Array, *, scale: float = 1.0,
                  interpret: bool = False) -> Array:
    """int8-stored multi-LoRA: A_q (U, d_in, r) int8 with A_scale (U, d_in, 1)
    per-row scales (likewise B). The bank stays int8 in HBM; dequant happens on
    tile load inside the kernel, so no f32 copy of the bank is ever
    materialised. Oracle: ref.multi_lora_q8."""
    T, d_in = x.shape
    U, _, r = A_q.shape
    d_out = B_q.shape[-1]
    bt = _block_t(T)
    y = pl.pallas_call(
        functools.partial(_q8_kernel, scale=scale, block_t=bt),
        grid=(T // bt, U),
        in_specs=[
            pl.BlockSpec((bt, d_in), lambda t, u: (t, 0)),
            pl.BlockSpec((1, d_in, r), lambda t, u: (u, 0, 0)),
            pl.BlockSpec((1, d_in, 1), lambda t, u: (u, 0, 0)),
            pl.BlockSpec((1, r, d_out), lambda t, u: (u, 0, 0)),
            pl.BlockSpec((1, r, 1), lambda t, u: (u, 0, 0)),
            pl.BlockSpec((bt,), lambda t, u: (t,)),
        ],
        out_specs=pl.BlockSpec((bt, d_out), lambda t, u: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((T, d_out), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, d_out), jnp.float32)],
        interpret=interpret,
    )(x, A_q, A_scale, B_q, B_scale, idx.astype(jnp.int32))
    return y
