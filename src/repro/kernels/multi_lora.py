"""Multi-LoRA kernel (Pallas): per-token adapter-indexed low-rank apply — the
FTaaS serving hot-spot (K users' adapters inside one decode batch; the BGMV
problem from Punica/S-LoRA, adapted to TPU).

TPU adaptation: instead of CUDA's per-warp gather of adapter weights, the grid
iterates (token-block x user); each user's (A_u, B_u) tile is a clean VMEM
block (index_map on the user axis), the token block computes the full low-rank
product on the MXU and masks rows that do not belong to user u before
accumulating. For K ~ tens of users this trades U-fold MXU passes (cheap,
r << d) for zero irregular memory access (expensive on TPU).

Oracle: repro.kernels.ref.multi_lora.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def supported(x, A, B, idx) -> bool:
    T, d_in = x.shape
    U, _, r = A.shape
    d_out = B.shape[-1]
    if d_in > 8192 or d_out > 8192 or r > 256 or U > 64:
        return False
    return T % _block_t(T) == 0 and _block_t(T) <= 256


def _block_t(t: int) -> int:
    for b in (256, 128, 64, 32, 16, 8):
        if t % b == 0 and b <= t:
            return b
    return t


PAD_ALIGN = 128


def pad_tokens(x: Array, idx: Array, align: int = PAD_ALIGN):
    """Pad the token axis to a kernel-friendly multiple (prefill batches are
    J*P tokens and rarely align). Padding rows carry user id -1, which matches
    no user block in the kernel mask and therefore contributes zeros; callers
    slice the output back to the original T. Returns None when already aligned.
    """
    from repro.utils import round_up
    T = x.shape[0]
    t2 = round_up(T, align)
    if t2 == T:
        return None
    xp = jnp.pad(x, ((0, t2 - T), (0, 0)))
    ip = jnp.pad(idx.astype(jnp.int32), (0, t2 - T), constant_values=-1)
    return xp, ip


def _kernel(x_ref, a_ref, b_ref, idx_ref, y_ref, acc_ref, *, scale, block_t):
    ui = pl.program_id(1)

    @pl.when(ui == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)          # (Bt, d_in)
    a = a_ref[0].astype(jnp.float32)            # (d_in, r)
    b = b_ref[0].astype(jnp.float32)            # (r, d_out)
    idx = idx_ref[...]                          # (Bt,)

    xa = jax.lax.dot_general(x, a, (((1,), (0,)), ((), ())))
    y = jax.lax.dot_general(xa, b, (((1,), (0,)), ((), ())))
    m = (idx == ui).astype(jnp.float32)[:, None]
    acc_ref[...] += y * m

    @pl.when(ui == pl.num_programs(1) - 1)
    def _final():
        y_ref[...] = (scale * acc_ref[...]).astype(y_ref.dtype)


def multi_lora(x: Array, A: Array, B: Array, idx: Array, *, scale: float = 1.0,
               interpret: bool = False) -> Array:
    T, d_in = x.shape
    U, _, r = A.shape
    d_out = B.shape[-1]
    bt = _block_t(T)
    y = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_t=bt),
        grid=(T // bt, U),
        in_specs=[
            pl.BlockSpec((bt, d_in), lambda t, u: (t, 0)),
            pl.BlockSpec((1, d_in, r), lambda t, u: (u, 0, 0)),
            pl.BlockSpec((1, r, d_out), lambda t, u: (u, 0, 0)),
            pl.BlockSpec((bt,), lambda t, u: (t,)),
        ],
        out_specs=pl.BlockSpec((bt, d_out), lambda t, u: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((T, d_out), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, d_out), jnp.float32)],
        interpret=interpret,
    )(x, A, B, idx.astype(jnp.int32))
    return y
