"""Flash attention for TPU (Pallas): block-tiled online-softmax with GQA,
causal + local-window masking and gemma2 logit softcap. Forward and backward
kernels with a custom_vjp wrapper.

TPU adaptation (vs the CUDA flash-attention the literature assumes):
- tiles are (block_q x d_head) / (block_k x d_head) VMEM blocks, MXU-aligned
  (block sizes multiples of 128; d_head 64/128/256);
- the kv-block loop is the innermost sequential grid dimension, with the
  online-softmax running stats (m, l) and the output accumulator living in
  VMEM scratch across iterations — the systolic analogue of warp-level
  accumulation;
- GQA is handled by the index_map (q-head h reads kv-head h // G), so kv tiles
  are never physically repeated.

Oracle: repro.kernels.ref.sdpa (uniform positions). Tests sweep shapes/dtypes
in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
NEG_INF = -1e30


def supported(q, k, v, *, q_positions=None, causal=True) -> bool:
    B, Sq, H, Dh = q.shape
    _, Sk, K, _ = k.shape
    if Dh not in (64, 128, 256):
        return False
    if H % K != 0:
        return False
    if Sq % _block_q(Sq) or Sk % _block_k(Sk):
        return False
    return True


def _block_q(sq: int) -> int:
    for b in (256, 128, 64, 32, 16, 8):
        if sq % b == 0 and b <= sq:
            return b
    return sq


def _block_k(sk: int) -> int:
    for b in (512, 256, 128, 64, 32, 16, 8):
        if sk % b == 0 and b <= sk:
            return b
    return sk


# ---------------------------------------------------------------------------
# forward kernel
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref, *,
                scale, causal, window, softcap, block_q, block_k, n_kv,
                q_offset):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)           # (Bq, Dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)           # (Bk, Dh)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (Bq, Bk)
    if softcap is not None and softcap > 0:
        s = jnp.tanh(s / softcap) * softcap

    qpos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None and window > 0:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    # guard fully-masked rows (m == NEG_INF): exp underflows to 0 anyway
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(3) - 1)
    def _final():
        l = l_ref[...]
        safe_l = jnp.where(l > 0, l, 1.0)
        o_ref[0, :, 0, :] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0, :] = jnp.where(
            l > 0, m_ref[...] + jnp.log(safe_l), NEG_INF)


def _fwd(q, k, v, *, scale, causal, window, softcap, q_offset, interpret):
    B, Sq, H, Dh = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    bq, bk = _block_q(Sq), _block_k(Sk)
    grid = (B, H, Sq // bq, Sk // bk)

    out_shapes = [
        jax.ShapeDtypeStruct((B, Sq, H, Dh), q.dtype),
        jax.ShapeDtypeStruct((B, H, Sq), jnp.float32),
    ]
    o, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, scale=scale, causal=causal,
                          window=window, softcap=softcap, block_q=bq,
                          block_k=bk, n_kv=K, q_offset=q_offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, Dh), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, Dh), lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, bk, 1, Dh), lambda b, h, qi, ki: (b, ki, h // G, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, 1, Dh), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, qi, ki: (b, h, qi)),
        ],
        out_shape=out_shapes,
        scratch_shapes=[
            pltpu.VMEM((bq, Dh), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return o, lse


# ---------------------------------------------------------------------------
# backward kernels
# ---------------------------------------------------------------------------

def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                   acc_ref, *, scale, causal, window, softcap, block_q,
                   block_k, q_offset):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    do = do_ref[0, :, 0, :].astype(jnp.float32)
    lse = lse_ref[0, 0, :]
    delta = delta_ref[0, 0, :]

    s_raw = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    if softcap is not None and softcap > 0:
        t = jnp.tanh(s_raw / softcap)
        s = t * softcap
        dcap = 1.0 - t * t
    else:
        s = s_raw
        dcap = None

    qpos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None and window > 0:
        mask = mask & (kpos > qpos - window)

    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - delta[:, None])
    if dcap is not None:
        ds = ds * dcap
    acc_ref[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ()))) * scale

    @pl.when(ki == pl.num_programs(3) - 1)
    def _final():
        dq_ref[0, :, 0, :] = acc_ref[...].astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, dk_acc, dv_acc, *, scale, causal, window,
                    softcap, block_q, block_k, q_offset):
    ki = pl.program_id(2)
    qi = pl.program_id(3)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0, :, 0, :].astype(jnp.float32)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    do = do_ref[0, :, 0, :].astype(jnp.float32)
    lse = lse_ref[0, 0, :]
    delta = delta_ref[0, 0, :]

    s_raw = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    if softcap is not None and softcap > 0:
        t = jnp.tanh(s_raw / softcap)
        s = t * softcap
        dcap = 1.0 - t * t
    else:
        s = s_raw
        dcap = None

    qpos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None and window > 0:
        mask = mask & (kpos > qpos - window)

    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)      # (Bq, Bk)
    dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - delta[:, None])
    if dcap is not None:
        ds = ds * dcap
    dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ()))) * scale

    @pl.when(qi == pl.num_programs(3) - 1)
    def _final():
        dk_ref[0, :, 0, :] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, :, 0, :] = dv_acc[...].astype(dv_ref.dtype)


def _bwd(res, g, *, scale, causal, window, softcap, q_offset, interpret):
    q, k, v, o, lse = res
    do = g
    B, Sq, H, Dh = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    bq, bk = _block_q(Sq), _block_k(Sk)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    delta = delta.transpose(0, 2, 1)                     # (B, H, Sq)

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, scale=scale, causal=causal,
                          window=window, softcap=softcap, block_q=bq,
                          block_k=bk, q_offset=q_offset),
        grid=(B, H, Sq // bq, Sk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, Dh), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, Dh), lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, bk, 1, Dh), lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, bq, 1, Dh), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, qi, ki: (b, h, qi)),
            pl.BlockSpec((1, 1, bq), lambda b, h, qi, ki: (b, h, qi)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, Dh), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, Dh), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # dk/dv accumulate over q-heads within each kv group: run per q-head and
    # sum the group afterwards (keeps the kernel simple; the sum is tiny).
    dkh, dvh = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, scale=scale, causal=causal,
                          window=window, softcap=softcap, block_q=bq,
                          block_k=bk, q_offset=q_offset),
        grid=(B, H, Sk // bk, Sq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, 1, Dh), lambda b, h, ki, qi: (b, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, Dh), lambda b, h, ki, qi: (b, ki, h // G, 0)),
            pl.BlockSpec((1, bk, 1, Dh), lambda b, h, ki, qi: (b, ki, h // G, 0)),
            pl.BlockSpec((1, bq, 1, Dh), lambda b, h, ki, qi: (b, qi, h, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, ki, qi: (b, h, qi)),
            pl.BlockSpec((1, 1, bq), lambda b, h, ki, qi: (b, h, qi)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, 1, Dh), lambda b, h, ki, qi: (b, ki, h, 0)),
            pl.BlockSpec((1, bk, 1, Dh), lambda b, h, ki, qi: (b, ki, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sk, H, Dh), jnp.float32),
            jax.ShapeDtypeStruct((B, Sk, H, Dh), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, Dh), jnp.float32),
            pltpu.VMEM((bk, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    dk = dkh.reshape(B, Sk, K, G, Dh).sum(axis=3).astype(k.dtype)
    dv = dvh.reshape(B, Sk, K, G, Dh).sum(axis=3).astype(v.dtype)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, scale, causal, window, softcap, q_offset, interpret):
    o, _ = _fwd(q, k, v, scale=scale, causal=causal, window=window,
                softcap=softcap, q_offset=q_offset, interpret=interpret)
    return o


def _flash_fwd(q, k, v, scale, causal, window, softcap, q_offset, interpret):
    o, lse = _fwd(q, k, v, scale=scale, causal=causal, window=window,
                  softcap=softcap, q_offset=q_offset, interpret=interpret)
    return o, (q, k, v, o, lse)


def _flash_bwd(scale, causal, window, softcap, q_offset, interpret, res, g):
    return _bwd(res, g, scale=scale, causal=causal, window=window,
                softcap=softcap, q_offset=q_offset, interpret=interpret)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q: Array, k: Array, v: Array, *, q_positions=None,
                    kv_positions=None, causal: bool = True,
                    window: int | None = None, softcap: float | None = None,
                    scale: float | None = None, q_offset: int = 0,
                    interpret: bool = False) -> Array:
    """Positions are assumed uniform (q starts at q_offset, kv at 0); the ref
    oracle handles arbitrary per-row positions (continuous batching decode)."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    return _flash(q, k, v, scale, causal, window, softcap, q_offset, interpret)
