"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *semantics* of the kernels: tests sweep shapes/dtypes and assert
``assert_allclose(kernel(interpret=True), ref)``. They are also the CPU fallback
used by the models in this container.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import flags

Array = jax.Array

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# scaled dot-product attention (flash_attention oracle)
# ---------------------------------------------------------------------------

def sdpa(q: Array, k: Array, v: Array, *, q_positions: Array,
         kv_positions: Array, causal: bool = True, window: int | None = None,
         softcap: float | None = None, scale: float | None = None,
         q_block: int = 512) -> Array:
    """Reference GQA attention (memory-efficient: scans over query blocks when
    Sq is large so the full (Sq, Sk) score matrix is never materialised).

    q: (B, Sq, H, Dh); k, v: (B, Sk, K, Dh) with H = K * G.
    q_positions: (B, Sq) or (1, Sq); kv_positions: (B, Sk) or (1, Sk).
    Masking: causal -> kv_pos <= q_pos; window -> kv_pos > q_pos - window.
    """
    Sq = q.shape[1]
    if Sq > 2 * q_block and Sq % q_block == 0 and not flags.get("dense_sdpa"):
        nb = Sq // q_block

        def blk(qb, qpb):
            return _sdpa_dense(qb, k, v, q_positions=qpb,
                               kv_positions=kv_positions, causal=causal,
                               window=window, softcap=softcap, scale=scale)

        qs = q.reshape(q.shape[0], nb, q_block, *q.shape[2:]).swapaxes(0, 1)
        qp = jnp.broadcast_to(q_positions, (q.shape[0], Sq))
        qps = qp.reshape(qp.shape[0], nb, q_block).swapaxes(0, 1)
        body = jax.checkpoint(lambda carry, xs: (carry, blk(*xs)))
        _, out = jax.lax.scan(body, (), (qs, qps), unroll=flags.scan_unroll())
        return out.swapaxes(0, 1).reshape(q.shape)
    return _sdpa_dense(q, k, v, q_positions=q_positions,
                       kv_positions=kv_positions, causal=causal, window=window,
                       softcap=softcap, scale=scale)


def _sdpa_dense(q: Array, k: Array, v: Array, *, q_positions: Array,
                kv_positions: Array, causal: bool = True,
                window: int | None = None, softcap: float | None = None,
                scale: float | None = None) -> Array:
    B, Sq, H, Dh = q.shape
    Bk, Sk, K, _ = k.shape
    G = H // K
    if scale is None:
        scale = Dh ** -0.5
    f32 = jnp.float32
    qp = q_positions.astype(jnp.int32)[:, None, :, None]   # (B,1,Sq,1)
    kp = kv_positions.astype(jnp.int32)[:, None, None, :]  # (B,1,1,Sk)
    mask = jnp.ones((B, 1, Sq, Sk), bool)
    if causal:
        mask = mask & (kp <= qp)
    if window is not None and window > 0:
        mask = mask & (kp > qp - window)

    if Sq > 1 or G == 1:
        # Train/prefill: expand kv heads to H. A (K,G) reshape of the sharded H
        # dim defeats GSPMD propagation (the head sharding becomes "diagonal"
        # over K and G); the repeat keeps one clean sharded H dim, and the
        # per-device repeat is a local slice of the (replicated) kv. kv stays
        # in its storage dtype; the MXU accumulates in f32.
        kf = jnp.repeat(k, G, axis=2) if G > 1 else k
        vf = jnp.repeat(v, G, axis=2) if G > 1 else v
        s = jnp.einsum("bqhd,bshd->bhqs", q, kf,
                       preferred_element_type=f32) * scale   # (B,H,Sq,Sk) f32
    else:
        # Decode (Sq == 1): never materialise a repeated (B,S,H,Dh) copy of the
        # KV cache — use the grouped form; the contraction runs over the
        # (sequence-sharded) cache directly.
        qg = q.reshape(B, Sq, K, G, Dh)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                       preferred_element_type=f32) * scale   # (B,K,G,Sq,Sk)
        s = s.reshape(B, H, Sq, Sk)
    if softcap is not None and softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no valid key (fully masked) produce uniform p; zero them out.
    any_valid = jnp.any(mask, axis=-1, keepdims=True)
    p = jnp.where(any_valid, p, 0.0)
    if Sq > 1 or G == 1:
        o = jnp.einsum("bhqs,bshd->bqhd", p.astype(q.dtype), vf,
                       preferred_element_type=f32)
    else:
        pg = p.reshape(B, K, G, Sq, Sk)
        o = jnp.einsum("bkgqs,bskd->bqkgd", pg.astype(q.dtype), v,
                       preferred_element_type=f32).reshape(B, Sq, H, Dh)
    return o.astype(q.dtype)


def sdpa_decode(q: Array, k_cache: Array, v_cache: Array, positions: Array, *,
                live: Array | None = None, window: int | None = None,
                softcap: float | None = None, scale: float | None = None) -> Array:
    """Incremental attention against a slot KV cache (fused-kernel oracle).
    q: (B, Sq, H, Dh) — Sq == 1 is the decode tick, Sq > 1 one chunk of a
    chunked prefill; caches: (B, Smax, K, Dh); positions: (B,) each row's
    *first* query position (query i sits at positions + i, and the cache is
    valid at kv_pos <= that query's position). ``live``: (B,) bool — non-live
    (dead/padding) slots return zeros, so their output is deterministic rather
    than garbage attention over a stale cache.
    """
    B, Sq = q.shape[0], q.shape[1]
    Smax = k_cache.shape[1]
    q_pos = positions.astype(jnp.int32)[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None]
    kv_pos = jnp.broadcast_to(jnp.arange(Smax, dtype=jnp.int32)[None],
                              (B, Smax))
    o = sdpa(q, k_cache, v_cache, q_positions=q_pos,
             kv_positions=kv_pos, causal=True, window=window, softcap=softcap,
             scale=scale)
    if live is not None:
        o = jnp.where(live[:, None, None, None], o, 0.0).astype(o.dtype)
    return o


def sdpa_decode_paged(q: Array, k_pool: Array, v_pool: Array, positions: Array,
                      block_table: Array, *, live: Array | None = None,
                      window: int | None = None, softcap: float | None = None,
                      scale: float | None = None) -> Array:
    """Paged-KV incremental attention (fused paged-kernel oracle).

    q: (B, Sq, H, Dh); pools: (n_blocks, block, K, Dh) shared across slots;
    block_table: (B, max_blocks) int32, position p of row b lives in pool block
    ``table[b, p // block]`` at offset ``p % block``. The oracle gathers each
    row's blocks back into a dense (B, max_blocks * block, K, Dh) view and
    defers to ``sdpa_decode`` — unallocated table entries point at block 0,
    whose (foreign) contents sit at kv positions beyond the row's allocated
    prefix and are position-masked. Bit-identical to the dense layout: the
    gathered prefix holds the same values and the masked tail contributes
    exact zeros either way.
    """
    kd = k_pool[block_table]        # (B, max_blocks, block, K, Dh)
    vd = v_pool[block_table]
    B, nb, bs = kd.shape[0], kd.shape[1], kd.shape[2]
    kd = kd.reshape(B, nb * bs, *kd.shape[3:])
    vd = vd.reshape(B, nb * bs, *vd.shape[3:])
    return sdpa_decode(q, kd, vd, positions, live=live, window=window,
                       softcap=softcap, scale=scale)


def sdpa_decode_ring(q: Array, k_ring: Array, v_ring: Array, positions: Array,
                     *, live: Array | None = None, window: int | None = None,
                     softcap: float | None = None,
                     scale: float | None = None) -> Array:
    """Rolling-window (ring) incremental attention — the pairs local-window
    layers under the paged layout keep only the last W_ring positions, with
    position p stored at ring index ``p % W_ring``.

    q: (B, Sq, H, Dh); rings: (B, W_ring, K, Dh); positions: (B,) first query
    position. The last *written* position is P = positions + Sq - 1 (the
    caller writes the chunk before attending). Ring index r holds the largest
    position ≡ r (mod W_ring) that is <= P; the gather below reorders the ring
    by ascending absolute position so the softmax/weighted-sum accumulate in
    the same order as the dense layout (bit-identity), assigning each entry
    its absolute kv position:

    - wrapped (P >= W_ring - 1): ordered index j maps to ring slot
      (P + 1 + j) % W_ring holding position P - W_ring + 1 + j.
    - not wrapped: ring slot j holds position j; slots beyond P are unwritten
      (or hold a padded chunk's future-position garbage) and their assigned
      position falls outside [qp - window, qp] — masked either way.

    Requires W_ring >= window + Sq - 1 (every query's full local window is
    still resident) — the cache-spec layer picks W_ring accordingly.
    """
    B, Sq = q.shape[0], q.shape[1]
    w_ring = k_ring.shape[1]
    pos = positions.astype(jnp.int32)
    last = pos + Sq - 1                                     # (B,) == P
    j = jnp.arange(w_ring, dtype=jnp.int32)[None]           # (1, W)
    wrapped = (last >= w_ring - 1)[:, None]
    ring_idx = jnp.where(wrapped, (last[:, None] + 1 + j) % w_ring, j)
    kv_pos = jnp.where(wrapped, last[:, None] - w_ring + 1 + j, j)
    kd = jnp.take_along_axis(k_ring, ring_idx[:, :, None, None], axis=1)
    vd = jnp.take_along_axis(v_ring, ring_idx[:, :, None, None], axis=1)
    q_pos = pos[:, None] + jnp.arange(Sq, dtype=jnp.int32)[None]
    o = sdpa(q, kd, vd, q_positions=q_pos, kv_positions=kv_pos, causal=True,
             window=window, softcap=softcap, scale=scale)
    if live is not None:
        o = jnp.where(live[:, None, None, None], o, 0.0).astype(o.dtype)
    return o


# ---------------------------------------------------------------------------
# cola_fit oracle: fused low-rank adapter fit gradient (the offloaded GL step)
# ---------------------------------------------------------------------------

def cola_fit_lowrank(x: Array, grad_h: Array, A: Array, B: Array,
                     scale: float = 1.0) -> tuple[Array, Array]:
    """Gradient of the paper's quadratic fit loss (Eq. 6) at w = w_t for the
    low-rank family — by Prop 1 this equals the true loss gradient.

      l(w) = 1/2 || g_w(x) - (dh_t - grad_h) ||^2,  g_w(x) = scale * (x A) B
      at w = w_t:  dl/dB = scale * (x A)^T grad_h ; dl/dA = scale * x^T (grad_h B^T)

    x: (T, d_in); grad_h: (T, d_out); A: (d_in, r); B: (r, d_out).
    """
    xf = x.astype(jnp.float32)
    gf = grad_h.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    xa = xf @ Af                                  # (T, r)
    dB = scale * (xa.T @ gf)                      # (r, d_out)
    dA = scale * (xf.T @ (gf @ Bf.T))             # (d_in, r)
    return dA, dB


# ---------------------------------------------------------------------------
# multi_lora oracle: per-token adapter-indexed low-rank apply (FTaaS serving)
# ---------------------------------------------------------------------------

def multi_lora(x: Array, A: Array, B: Array, idx: Array,
               scale: float = 1.0) -> Array:
    """y[t] = scale * (x[t] @ A[idx[t]]) @ B[idx[t]].

    x: (T, d_in); A: (U, d_in, r); B: (U, r, d_out); idx: (T,) int32 in [0, U).
    Rows with idx < 0 are padding and contribute exactly zero (the kernel's
    user mask never matches them; the oracle must agree).
    """
    safe = jnp.clip(idx, 0, A.shape[0] - 1)
    a = A[safe].astype(jnp.float32)               # (T, d_in, r)
    b = B[safe].astype(jnp.float32)               # (T, r, d_out)
    xa = jnp.einsum("td,tdr->tr", x.astype(jnp.float32), a)
    y = jnp.einsum("tr,tro->to", xa, b)
    y = jnp.where((idx >= 0)[:, None], y, 0.0)
    return (scale * y).astype(x.dtype)


def multi_lora_q8(x: Array, A_q: Array, A_scale: Array, B_q: Array,
                  B_scale: Array, idx: Array, scale: float = 1.0) -> Array:
    """int8-stored multi-LoRA oracle. A_q: (U, d_in, r) int8 with per-row
    scales A_scale: (U, d_in, 1); likewise B. Dequantises only the T gathered
    per-token adapters — never a f32 copy of the full U-entry bank. Rows with
    idx < 0 are padding and contribute exactly zero."""
    safe = jnp.clip(idx, 0, A_q.shape[0] - 1)
    a = A_q[safe].astype(jnp.float32) * A_scale[safe].astype(jnp.float32)
    b = B_q[safe].astype(jnp.float32) * B_scale[safe].astype(jnp.float32)
    xa = jnp.einsum("td,tdr->tr", x.astype(jnp.float32), a)
    y = jnp.einsum("tr,tro->to", xa, b)
    y = jnp.where((idx >= 0)[:, None], y, 0.0)
    return (scale * y).astype(x.dtype)


# ---------------------------------------------------------------------------
# ssd oracle: mamba2 state-space duality (quadratic within-chunk form)
# ---------------------------------------------------------------------------

def _segsum(log_decay: Array) -> Array:
    """Stable segment sum: seg[i, j] = sum_{k=j+1..i} log_decay_k (j <= i).

    The naive form ``cum_i - cum_j`` differences two global-cumsum anchors; at
    long S the anchors grow to O(S) magnitude while the segment sum stays O(1)
    for nearby (i, j), so float32 cancellation corrupts exactly the decay
    entries that matter (the seed ``ssd_chunked[512-128]`` failure). Instead,
    accumulate each column j directly from position j+1 (the Mamba2 repo's
    "more stable segment sum"): mask log_decay to the strict lower triangle and
    cumsum along i — every segment sum is then built only from its own terms.

    log_decay: (b, S, H) -> (b, S, S, H) with axis 1 = i, axis 2 = j.
    """
    S = log_decay.shape[1]
    strict = jnp.tril(jnp.ones((S, S), bool), -1)              # i > j
    terms = jnp.where(strict[None, :, :, None],
                      log_decay[:, :, None, :], 0.0)           # (b,i,j,H)
    return jnp.cumsum(terms, axis=1)


def ssd(x: Array, dt: Array, a: Array, B: Array, C: Array, D: Array,
        init_state: Array | None = None) -> tuple[Array, Array]:
    """Reference SSD (naive O(S^2) masked-attention form, per Mamba2 paper).

    x : (b, S, H, P)   inputs per head
    dt: (b, S, H)      positive step sizes (already softplus'ed)
    a : (H,)           negative decay rate per head (A = -exp(a_log))
    B : (b, S, N)      input projections (ngroups = 1)
    C : (b, S, N)      output projections
    D : (H,)           skip connection
    init_state: (b, H, P, N) or None
    Returns (y: (b,S,H,P), final_state: (b,H,P,N)).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    af = a.astype(jnp.float32)

    log_decay = dtf * af[None, None, :]                   # (b,S,H)  (negative)
    cum = jnp.cumsum(log_decay, axis=1)                   # (b,S,H)
    # L[i,j] = exp(sum_{k=j+1..i} log_decay_k) for j <= i else 0, via the
    # stable segment sum (see _segsum for why not exp(cum_i - cum_j)).
    seg = _segsum(log_decay)                              # (b,Sq,Sk,H)
    causal = jnp.tril(jnp.ones((S, S), bool))
    Lmat = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
    # scores[i,j] = C_i . B_j
    cb = jnp.einsum("bin,bjn->bij", Cf, Bf)               # (b,S,S)
    w = cb[:, :, :, None] * Lmat                          # (b,Sq,Sk,H)
    y = jnp.einsum("bijh,bjh,bjhp->bihp", w, dtf, xf)     # (b,S,H,P)

    if init_state is not None:
        sf = init_state.astype(jnp.float32)               # (b,H,P,N)
        decay_from_start = jnp.exp(cum)                   # (b,S,H)
        y = y + jnp.einsum("bin,bhpn,bih->bihp", Cf, sf, decay_from_start)

    # final state: sum_j exp(sum_{k=j+1..S} log_decay_k) dt_j B_j x_j
    # (+ carried init state); the decay-to-end row is seg[S-1, :].
    total = cum[:, -1, :]                                 # (b,H)
    decay_to_end = jnp.exp(seg[:, -1, :, :])              # (b,S,H)
    state = jnp.einsum("bjh,bjh,bjhp,bjn->bhpn", decay_to_end, dtf, xf, Bf)
    if init_state is not None:
        state = state + init_state.astype(jnp.float32) * jnp.exp(total)[:, :, None, None]
    y = y + xf * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), state.astype(jnp.float32)


def ssd_decode_step(x: Array, dt: Array, a: Array, B: Array, C: Array, D: Array,
                    state: Array) -> tuple[Array, Array]:
    """Single-token SSD recurrence.

    x: (b,H,P); dt: (b,H); B,C: (b,N); state: (b,H,P,N).
    """
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = B.astype(jnp.float32), C.astype(jnp.float32)
    decay = jnp.exp(dtf * a.astype(jnp.float32)[None, :])            # (b,H)
    state = state * decay[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dtf, xf, Bf)
    y = (jnp.einsum("bhpn,bn->bhp", state, Cf)
         + xf * D.astype(jnp.float32)[None, :, None])
    return y.astype(x.dtype), state
