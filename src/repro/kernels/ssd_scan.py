"""Chunked SSD scan (Mamba2 "state-space duality", linear time).

The sequence is split into chunks of length ``chunk``; each chunk applies the
quadratic masked form (``ref.ssd``) locally and carries the (H, P, N) state
across chunks with ``lax.scan``. On TPU the per-chunk quadratic form is dense
MXU work; the scan carries only the small state in registers/VMEM.

``ssd_chunk_pallas`` is the Pallas intra-chunk kernel (TPU target) used when the
backend requests it; the jnp chunked path is the oracle-equivalent default.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import flags
from repro.kernels import ref

Array = jax.Array


def ssd_chunked(x: Array, dt: Array, a: Array, B: Array, C: Array, D: Array,
                init_state: Array | None = None, *, chunk: int = 128,
                backend: str = "ref") -> tuple[Array, Array]:
    b, S, H, P = x.shape
    N = B.shape[-1]
    if S % chunk != 0:
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk

    def to_chunks(t):
        return t.reshape(t.shape[0], nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xs = (to_chunks(x), to_chunks(dt), to_chunks(B), to_chunks(C))
    state0 = (jnp.zeros((b, H, P, N), jnp.float32) if init_state is None
              else init_state.astype(jnp.float32))

    def body(state, inp):
        xc, dtc, Bc, Cc = inp
        yc, state = ref.ssd(xc, dtc, a, Bc, Cc, D, init_state=state)
        return state, yc

    state, ys = jax.lax.scan(body, state0, xs, unroll=flags.scan_unroll())
    y = ys.swapaxes(0, 1).reshape(b, nc * chunk, H, P)[:, :S]
    return y, state
