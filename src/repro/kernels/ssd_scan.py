"""Chunked SSD scan (Mamba2 "state-space duality", linear time).

The sequence is split into chunks of length ``chunk``; each chunk applies the
quadratic masked form (``ref.ssd``) locally and carries the (H, P, N) state
across chunks with ``lax.scan``. On TPU the per-chunk quadratic form is dense
MXU work; the scan carries only the small state in registers/VMEM.

``ssd_chunk_pallas`` is the Pallas intra-chunk kernel (TPU target) used when the
backend requests it; the jnp chunked path is the oracle-equivalent default.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import flags
from repro.kernels import ref

Array = jax.Array


def ssd_chunked(x: Array, dt: Array, a: Array, B: Array, C: Array, D: Array,
                init_state: Array | None = None, *, chunk: int = 128,
                backend: str = "ref") -> tuple[Array, Array]:
    """Linear-time chunked scan; exact for any S.

    A non-divisible tail is handled as one exact-length ``ref.ssd`` call seeded
    with the scanned carry rather than by zero-padding the last chunk: padded
    positions with dt == 0 happen to be state-preserving *only* because this
    parameterisation multiplies both the decay exponent and the input by dt —
    any other discretisation would silently corrupt the returned final state.
    With the tail sliced exactly, the returned state is provably the state at
    position S (tests assert it equals the step-by-step decode state).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    nc, tail = divmod(S, chunk)
    state = (jnp.zeros((b, H, P, N), jnp.float32) if init_state is None
             else init_state.astype(jnp.float32))
    if nc == 0:
        return ref.ssd(x, dt, a, B, C, D, init_state=state)

    head = nc * chunk

    def to_chunks(t):
        return t.reshape(t.shape[0], nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xs = (to_chunks(x[:, :head]), to_chunks(dt[:, :head]),
          to_chunks(B[:, :head]), to_chunks(C[:, :head]))

    def body(state, inp):
        xc, dtc, Bc, Cc = inp
        yc, state = ref.ssd(xc, dtc, a, Bc, Cc, D, init_state=state)
        return state, yc

    state, ys = jax.lax.scan(body, state, xs, unroll=flags.scan_unroll())
    y = ys.swapaxes(0, 1).reshape(b, head, H, P)
    if tail:
        y_tail, state = ref.ssd(x[:, head:], dt[:, head:], a, B[:, head:],
                                C[:, head:], D, init_state=state)
        y = jnp.concatenate([y, y_tail], axis=1)
    return y, state
