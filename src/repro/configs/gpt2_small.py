"""gpt2-small analogue — the paper's own CLM base model family (Table 6/12).
12L d_model=768 12H MHA d_ff=3072 vocab=50257. Used by the paper-table
benchmarks; not part of the assigned 10-arch pool.
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="gpt2-small", family="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_head=64,
        d_ff=3072, vocab_size=50257, rope_theta=1e4,
        param_dtype="float32", compute_dtype="float32", remat="none",
    )
