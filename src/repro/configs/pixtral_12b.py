"""pixtral-12b [vlm]: pixtral-ViT frontend (STUBBED: input_specs provides
precomputed patch embeddings) + mistral-nemo backbone: 40L d_model=5120 32H
(GQA kv=8) d_ff=14336 vocab=131072. [hf:mistralai/Pixtral-12B-2409; unverified]
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_head=128,
        d_ff=14336, vocab_size=131072, rope_theta=1e6,
        embed_input=True,
        microbatches=8,
    )
