"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) per-expert
d_ff=768, vocab=151936, MoE 128 experts top-8, head_dim=128, QK-norm.
[hf:Qwen/Qwen3-30B-A3B; hf]
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_head=128,
        d_ff=0, vocab_size=151936, qk_norm=True, rope_theta=1e6,
        n_experts=128, moe_top_k=8, d_expert=768, moe_impl="einsum",
        microbatches=4,
    )
