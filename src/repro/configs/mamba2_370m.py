"""mamba2-370m [ssm]: 48L d_model=1024 (attention-free), vocab=50280,
ssm_state=128, headdim=64, expand=2 (d_inner=2048, 32 SSD heads).
SSD = state-space duality. [arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family="ssm",
        n_layers=48, d_model=1024, d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_conv=4,
        ssd_chunk=128, tie_embeddings=True,
        microbatches=2,
    )
