"""Architecture registry: configs, reduced smoke variants, shape cells and
ShapeDtypeStruct input specs for the dry-run.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.utils import canonical_dtype

ARCH_MODULES = {
    "musicgen-medium": "repro.configs.musicgen_medium",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "gemma2-9b": "repro.configs.gemma2_9b",
    "smollm-135m": "repro.configs.smollm_135m",
    "mamba2-370m": "repro.configs.mamba2_370m",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "gpt2-small": "repro.configs.gpt2_small",
}

ASSIGNED = tuple(k for k in ARCH_MODULES if k != "gpt2-small")


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(ARCH_MODULES[name])
    return mod.get_config()


def reduced_config(name: str) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests (one fwd/train step)."""
    cfg = get_config(name)
    kw: dict = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        vocab_size=min(cfg.vocab_size, 512),
        param_dtype="float32", compute_dtype="float32", remat="none",
        loss_chunk=0,
    )
    if cfg.n_heads:
        kw.update(n_heads=4, d_head=32,
                  n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4)
    if cfg.d_ff:
        kw.update(d_ff=256)
    if cfg.n_experts:
        kw.update(n_experts=8, moe_top_k=2, d_expert=64)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_headdim=16, ssd_chunk=32)
    if cfg.shared_attn_every:
        kw.update(n_layers=7, shared_attn_every=3)
    if cfg.attn_pattern == "local_global":
        kw.update(local_window=16)
    return cfg.replace(**kw)


# ---------------------------------------------------------------------------
# shape cells
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """Skip rules: long_500k only for sub-quadratic (SSM / hybrid) archs."""
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        shapes.append("long_500k")
    return shapes


def all_cells() -> list[tuple[str, str]]:
    cells = []
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for s in applicable_shapes(cfg):
            cells.append((arch, s))
    return cells


def skipped_cells() -> list[tuple[str, str, str]]:
    out = []
    for arch in ASSIGNED:
        cfg = get_config(arch)
        if not cfg.sub_quadratic:
            out.append((arch, "long_500k",
                        "full quadratic attention; 500k ctx requires sub-quadratic"))
    return out


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, canonical_dtype(dtype) if isinstance(dtype, str) else dtype)


def batch_specs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """Training/prefill batch input specs for one model."""
    if cfg.embed_input:
        return {
            "embeds": _sds((batch, seq, cfg.d_model), cfg.compute_dtype),
            "labels": _sds((batch, seq), jnp.int32),
        }
    if cfg.n_codebooks:
        return {
            "tokens": _sds((batch, seq, cfg.n_codebooks), jnp.int32),
            "labels": _sds((batch, seq, cfg.n_codebooks), jnp.int32),
        }
    return {
        "tokens": _sds((batch, seq), jnp.int32),
        "labels": _sds((batch, seq), jnp.int32),
    }


def decode_token_specs(cfg: ModelConfig, batch: int) -> dict:
    if cfg.embed_input:
        tok = {"embeds": _sds((batch, 1, cfg.d_model), cfg.compute_dtype)}
    elif cfg.n_codebooks:
        tok = {"tokens": _sds((batch, 1, cfg.n_codebooks), jnp.int32)}
    else:
        tok = {"tokens": _sds((batch, 1), jnp.int32)}
    tok["positions"] = _sds((batch,), jnp.int32)
    return tok


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a dry-run cell.

    For decode cells the KV/SSM cache specs are produced by the model module
    (``repro.models.model.cache_specs``) and merged in by the dry-run driver.
    """
    spec = SHAPES[shape_name]
    if spec.kind in ("train", "prefill"):
        return batch_specs(cfg, spec.batch, spec.seq)
    return decode_token_specs(cfg, spec.batch)
