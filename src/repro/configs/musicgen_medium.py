"""musicgen-medium [audio]: decoder-only over EnCodec tokens.
48L d_model=1536 24H (GQA kv=24 == MHA) d_ff=6144 vocab=2048, 4 codebooks.
[arXiv:2306.05284; hf] — modality frontend stubbed: the backbone consumes the
4 EnCodec token streams directly (summed codebook embeddings, 4 output heads).
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", family="dense",
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, d_head=64,
        d_ff=6144, vocab_size=2048, n_codebooks=4,
        rope_theta=1e4, tie_embeddings=False,
        microbatches=4,
    )
