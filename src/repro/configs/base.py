"""Config system: ModelConfig (architecture), TrainConfig (ColA/optimizer),
MeshConfig. Configs are frozen dataclasses -> hashable -> usable as jit static
arguments.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    # attention
    rope_theta: float = 1e4
    qk_norm: bool = False
    attn_pattern: str = "global"   # "global" | "local_global" (alternating pairs)
    local_window: int = 4096
    attn_softcap: float = 0.0      # gemma2: 50.0
    final_softcap: float = 0.0     # gemma2: 30.0
    act: str = "silu"              # "silu" | "gelu"
    post_norm: bool = False        # gemma2 post-layernorms
    norm_plus_one: bool = False    # gemma-style (1+scale) rmsnorm
    embed_scale: bool = False      # gemma-style sqrt(d_model) embedding scaling
    # moe
    n_experts: int = 0
    moe_top_k: int = 0
    d_expert: int = 0
    moe_impl: str = "einsum"       # "einsum" (GShard baseline) | "sort" (optimized)
    capacity_factor: float = 1.25
    moe_group: int = 512           # GShard dispatch group size (tokens)
    aux_loss_coef: float = 0.01
    # ssm (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssd_chunk: int = 128
    shared_attn_every: int = 0     # zamba2: one shared attn block every N layers
    # modality stubs
    n_codebooks: int = 0           # musicgen: EnCodec codebooks
    embed_input: bool = False      # pixtral: inputs are precomputed patch embeds
    # misc
    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "full"            # "none" | "full" | "dots"
    loss_chunk: int = 0            # >0: chunked cross-entropy over seq (memory opt)
    microbatches: int = 1          # grad-accumulation splits inside train_step
    shard_policy: str = "2d"       # "2d" (DP+FSDP+TP) | "dp" (pure data parallel
                                   # over every mesh axis; for small models whose
                                   # heads/dims do not divide the model axis)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM / hybrid only; gemma2's global
        layers make it quadratic, so alternating local/global does NOT count)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ColaConfig:
    """How ColA is attached to a model (static)."""
    mode: str = "fused_fit"        # "faithful_offload" (Mode A) | "fused_fit" (Mode B)
                                   # | "lora" (classic PEFT baseline) | "ft" | "frozen"
    family: str = "lowrank"        # adapter family for all taps ("lowrank"|"linear"|"mlp")
    taps: str = "qv"               # "qv" | "all_attn" | "mlp" | "all" | "ssm"
    rank: int = 8
    hidden: int = 128
    scale: float = 1.0
    merged: bool = False           # parameter merging during training (Alg.1 l.3/8)
    interval: int = 1              # adaptation interval I
    users: int = 1                 # K collaborative users
    compress: str = "none"         # "none" | "int8" (offload compression)


@dataclass(frozen=True)
class TrainConfig:
    batch: int = 32
    seq: int = 128
    lr: float = 3e-4
    weight_decay: float = 5e-4
    warmup: float = 0.05
    steps: int = 100
    optimizer: str = "adamw"
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    grad_clip: float = 1.0
    schedule: str = "linear"       # "linear" | "cosine" | "const"
    seed: int = 0


@dataclass(frozen=True)
class MeshConfig:
    data: int = 16
    model: int = 16
    pods: int = 1

    @property
    def devices(self) -> int:
        return self.data * self.model * self.pods
