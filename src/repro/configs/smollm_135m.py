"""smollm-135m [dense]: 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152,
llama-arch small, head_dim=64. [hf:HuggingFaceTB/SmolLM-135M; hf]
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m", family="dense",
        n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, d_head=64,
        d_ff=1536, vocab_size=49152, rope_theta=1e4,
    )
