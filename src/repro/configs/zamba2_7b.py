"""zamba2-7b [hybrid]: 81 Mamba2 layers d_model=3584 + shared attention block
(32H kv=32 MHA, d_ff=14336) applied every 6 layers, vocab=32000, ssm_state=64.
[arXiv:2411.15242; unverified]
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_head=112,
        d_ff=14336, vocab_size=32000,
        ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_conv=4,
        shared_attn_every=6, ssd_chunk=128,
        microbatches=8,
    )
