"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) per-expert d_ff=10752,
vocab=100352, MoE 16 experts top-4 fine-grained, head_dim=128.
[hf:databricks/dbrx-base; unverified]
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
        d_ff=0, vocab_size=100352, rope_theta=5e5,
        n_experts=16, moe_top_k=4, d_expert=10752, moe_impl="einsum",
        microbatches=8,
    )
