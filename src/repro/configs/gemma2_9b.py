"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000,
local(4096)+global alternating, attn softcap 50 / final softcap 30, GeGLU,
pre+post norms, head_dim=256. [arXiv:2408.00118; hf]
"""
from repro.configs.base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b", family="dense",
        n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, d_head=256,
        d_ff=14336, vocab_size=256000,
        attn_pattern="local_global", local_window=4096,
        attn_softcap=50.0, final_softcap=30.0, act="gelu",
        post_norm=True, norm_plus_one=True, embed_scale=True,
        rope_theta=1e4, loss_chunk=512,
        microbatches=4,
    )
