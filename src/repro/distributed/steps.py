"""pjit step builders: sharded train_step / serve_step for every (arch, mode).

These are the functions the dry-run lowers and the launcher runs. All of them
wrap the same ``repro.core.gl`` math used by the single-host session — the
distribution layer adds shardings, never changes semantics.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import flags
from repro.configs.base import ColaConfig, ModelConfig
from repro.core import gl
from repro.core import taps as taps_lib
from repro.distributed import sharding as sh
from repro.models import model as model_lib

Array = jax.Array


# ---------------------------------------------------------------------------
# shape-only param/adapters trees (no allocation — dry-run safe)
# ---------------------------------------------------------------------------

def shaped_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: model_lib.init(cfg, jax.random.PRNGKey(0)))


def shaped_adapters(cfg: ModelConfig, cc: ColaConfig):
    if cc.mode in ("ft", "frozen"):
        return {}
    return jax.eval_shape(
        lambda: gl.init_adapters(cfg, cc, jax.random.PRNGKey(0),
                                 dtype=jnp.float32))


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, cc: ColaConfig, mesh: Mesh):
    """Returns (fn, in_shardings, donate) for jax.jit; fn signature depends on
    mode:
      fused_fit / lora : fn(params, adapters, batch) -> (loss, adapter_grads)
      faithful_offload : fn(params, adapters, batch) -> (loss, adaptation_data)
      ft               : fn(params, batch) -> (loss, param_grads)
    """
    spec = gl.make_spec(cfg, cc)

    if cc.mode == "ft":
        def fn_ft(params, batch):
            with sh.activation_rules(mesh, cfg.shard_policy):
                loss, grads, _ = gl.train_step_ft(cfg, params, batch)
            return loss, grads

        ps = sh.params_shardings(mesh, shaped_params(cfg),
                                 policy=cfg.shard_policy)
        return fn_ft, (ps, None), ()

    def split_micro(batch):
        m = cfg.microbatches
        return jax.tree.map(
            lambda x: x.reshape(m, x.shape[0] // m, *x.shape[1:]), batch)

    if cc.mode == "faithful_offload":
        def fn_a(params, adapters, batch):
            with sh.activation_rules(mesh, cfg.shard_policy):
                if cfg.microbatches > 1:
                    def body(carry, b):
                        loss, data, _ = gl.server_step_a(cfg, spec, params,
                                                         adapters, b)
                        return carry + loss, data

                    tot, data = jax.lax.scan(
                        body, jnp.zeros(()), split_micro(batch),
                        unroll=flags.scan_unroll())
                    # data leaves: (M, L?, b, S, d) — per-microbatch adaptation
                    # data, streamed to the offloader as M pushes.
                    return tot / cfg.microbatches, data
                loss, data, _ = gl.server_step_a(cfg, spec, params, adapters,
                                                 batch)
            return loss, data

        fn = fn_a
    else:
        def fn_b(params, adapters, batch):
            with sh.activation_rules(mesh, cfg.shard_policy):
                if cfg.microbatches > 1:
                    zeros = jax.tree.map(jnp.zeros_like, adapters)

                    def body(carry, b):
                        tot, acc = carry
                        loss, grads, _ = gl.train_step_b(cfg, spec, params,
                                                         adapters, b)
                        return (tot + loss,
                                jax.tree.map(jnp.add, acc, grads)), None

                    (tot, acc), _ = jax.lax.scan(
                        body, (jnp.zeros(()), zeros), split_micro(batch),
                        unroll=flags.scan_unroll())
                    m = float(cfg.microbatches)
                    return tot / m, jax.tree.map(lambda g: g / m, acc)
                loss, grads, _ = gl.train_step_b(cfg, spec, params, adapters,
                                                 batch)
            return loss, grads

        fn = fn_b

    ps = sh.params_shardings(mesh, shaped_params(cfg), policy=cfg.shard_policy)
    ash = sh.params_shardings(mesh, shaped_adapters(cfg, cc), adapter=True,
                              policy=cfg.shard_policy)
    return fn, (ps, ash, None), ()


# ---------------------------------------------------------------------------
# serve step (decode)
# ---------------------------------------------------------------------------

def make_serve_step(cfg: ModelConfig, mesh: Mesh, greedy: bool = True):
    """fn(params, cache, batch) -> (tokens|logits, new_cache). Cache donated."""

    def fn(params, cache, batch):
        with sh.activation_rules(mesh, cfg.shard_policy):
            logits, cache = model_lib.decode_step(cfg, params, batch, cache)
        if greedy:
            out = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            out = logits
        return out, cache

    ps = sh.params_shardings(mesh, shaped_params(cfg), policy=cfg.shard_policy)
    return fn, ps


def serve_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int):
    cache_sh = sh.cache_shardings(mesh, model_lib.cache_specs(cfg, batch, max_len))
    from repro.configs import registry
    tok = sh.batch_shardings(mesh, registry.decode_token_specs(cfg, batch),
                             policy=cfg.shard_policy)
    return cache_sh, tok


# ---------------------------------------------------------------------------
# prefill step
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, mesh: Mesh):
    def fn(params, batch):
        with sh.activation_rules(mesh, cfg.shard_policy):
            return model_lib.prefill(cfg, params, batch)

    ps = sh.params_shardings(mesh, shaped_params(cfg), policy=cfg.shard_policy)
    return fn, ps


def prefill_out_shardings(cfg: ModelConfig, mesh: Mesh, batch: int,
                          max_len: int):
    """Logits replicated-ish (tiny); cache sharded like the decode cache so the
    prefill output feeds serve_step without resharding (and so the stacked KV
    never materialises replicated when kv-heads don't divide the model axis)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    logits_shape = ((batch, 1, cfg.n_codebooks, cfg.vocab_size)
                    if cfg.n_codebooks else (batch, 1, cfg.vocab_size))
    ba = sh.batch_axes(mesh)
    nb = 1
    for a in ba:
        nb *= mesh.shape[a]
    lspec = [None] * len(logits_shape)
    if batch % nb == 0:
        lspec[0] = ba
    if logits_shape[-1] % mesh.shape.get("model", 1) == 0:
        lspec[-1] = "model"
    logits_sh = NamedSharding(mesh, P(*lspec))
    cache_sh = sh.cache_shardings(mesh, model_lib.cache_specs(cfg, batch,
                                                              max_len))
    return logits_sh, cache_sh
