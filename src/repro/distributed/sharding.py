"""Sharding rules: DP (pod+data), FSDP (params over data), TP (model), EP
(experts over model), SP (long sequences over model) — with divisibility-guarded
fallbacks so every assigned arch shards cleanly on the production mesh.

Two mechanisms:
1. ``params_shardings`` / ``batch_shardings`` / ``cache_shardings`` — explicit
   NamedShardings for jit in/out_shardings (path-pattern rules).
2. ``constrain`` — lightweight activation sharding constraints the model code
   calls at strategic points; a no-op unless an ``activation_rules`` context is
   active (so CPU tests pay nothing).
"""
from __future__ import annotations

import contextlib
import re
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# ---------------------------------------------------------------------------
# activation constraints
# ---------------------------------------------------------------------------

_RULES: list["ActivationRules"] = []


class ActivationRules:
    def __init__(self, mesh: Mesh, policy: str = "2d"):
        self.mesh = mesh
        if policy == "dp":
            self.batch_axes = tuple(a for a in ("pod", "data", "model")
                                    if a in mesh.axis_names)
            self.model_axis = None
        else:
            self.batch_axes = tuple(a for a in ("pod", "data")
                                    if a in mesh.axis_names)
            self.model_axis = "model" if "model" in mesh.axis_names else None

    def axis_size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n


@contextlib.contextmanager
def activation_rules(mesh: Mesh, policy: str = "2d"):
    _RULES.append(ActivationRules(mesh, policy))
    try:
        yield _RULES[-1]
    finally:
        _RULES.pop()


def current_rules() -> ActivationRules | None:
    return _RULES[-1] if _RULES else None


def constrain(x: jax.Array, *dims: str | None) -> jax.Array:
    """Constrain x's sharding. dims entries: "batch", "model", None. Dims that
    don't divide are silently replicated. No-op outside an activation_rules
    context."""
    r = current_rules()
    if r is None:
        return x
    spec = []
    for d, size in zip(dims, x.shape):
        if d == "batch" and r.batch_axes and size % r.axis_size(r.batch_axes) == 0 and size > 0:
            spec.append(r.batch_axes)
        elif d == "model" and r.model_axis and size % r.axis_size(r.model_axis) == 0 and size > 0:
            spec.append(r.model_axis)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(r.mesh, P(*spec)))


# ---------------------------------------------------------------------------
# parameter shardings (path-pattern rules)
# ---------------------------------------------------------------------------

def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _axis(mesh: Mesh, name: str) -> int:
    return mesh.shape.get(name, 1)


def _pick(mesh: Mesh, size: int, *candidates):
    """First candidate axis (or axis tuple) that divides ``size``."""
    for cand in candidates:
        if cand is None:
            continue
        axes = (cand,) if isinstance(cand, str) else tuple(cand)
        if all(a in mesh.axis_names for a in axes):
            k = 1
            for a in axes:
                k *= mesh.shape[a]
            if _div(size, k):
                return cand
    return None


def _param_spec(mesh: Mesh, path: str, shape: tuple[int, ...],
                policy: str = "2d") -> P:
    """Sharding rule for one parameter leaf, identified by its dotted path."""
    nd = len(shape)
    if policy == "dp":
        fs = ("data", "model")   # pure-DP: FSDP over both axes, no TP
        mdl = None
    else:
        fs = "data"   # FSDP axis (within-pod; pods replicate frozen base params)
        mdl = "model"

    def spec_nd(*tail):
        """Pad with leading Nones for stacked (L, ...) leaves."""
        lead = nd - len(tail)
        return P(*([None] * lead + list(tail)))

    # embeddings / heads ----------------------------------------------------
    if re.search(r"(embed|unembed)\.emb$", path):
        v, d = shape[-2], shape[-1]
        return spec_nd(_pick(mesh, v, (mdl, fs), mdl, fs), None)
    if path.endswith("lm_head.w"):
        return spec_nd(_pick(mesh, shape[-2], fs), _pick(mesh, shape[-1], mdl))
    # attention ---------------------------------------------------------------
    if re.search(r"attn\.(q|k|v)\.w$", path):
        return spec_nd(_pick(mesh, shape[-2], fs), _pick(mesh, shape[-1], mdl))
    if path.endswith("attn.o.w"):
        return spec_nd(_pick(mesh, shape[-2], mdl), _pick(mesh, shape[-1], fs))
    # dense mlp ---------------------------------------------------------------
    if re.search(r"mlp\.(gate|up)\.w$", path):
        return spec_nd(_pick(mesh, shape[-2], fs), _pick(mesh, shape[-1], mdl))
    if path.endswith("mlp.down.w"):
        return spec_nd(_pick(mesh, shape[-2], mdl), _pick(mesh, shape[-1], fs))
    # moe ---------------------------------------------------------------------
    if path.endswith("router.w"):
        return spec_nd(None, None)
    if re.search(r"moe\.(gate|up)$", path):
        return spec_nd(_pick(mesh, shape[-3], mdl), _pick(mesh, shape[-2], fs), None)
    if path.endswith("moe.down"):
        return spec_nd(_pick(mesh, shape[-3], mdl), None, _pick(mesh, shape[-1], fs))
    # ssm ---------------------------------------------------------------------
    if path.endswith("ssm.in_proj.w"):
        return spec_nd(_pick(mesh, shape[-2], fs), None)
    if path.endswith("ssm.out_proj.w"):
        return spec_nd(_pick(mesh, shape[-2], mdl), _pick(mesh, shape[-1], fs))
    # everything small (norms, conv, biases, A_log, D) ------------------------
    return P(*([None] * nd))


def _adapter_spec(mesh: Mesh, path: str, shape: tuple[int, ...],
                  policy: str = "2d") -> P:
    nd = len(shape)
    if policy == "dp":
        fs, mdl = ("data", "model"), None
    else:
        fs, mdl = "data", "model"

    def spec_nd(*tail):
        lead = nd - len(tail)
        return P(*([None] * lead + list(tail)))

    if path.endswith(".A"):        # (L?, d_in, r)
        return spec_nd(_pick(mesh, shape[-2], fs), None)
    if path.endswith(".B"):        # (L?, r, d_out)
        return spec_nd(None, _pick(mesh, shape[-1], mdl))
    if path.endswith(".W"):        # linear (L?, d_in, d_out)
        return spec_nd(_pick(mesh, shape[-2], fs), _pick(mesh, shape[-1], mdl))
    if path.endswith(".W1"):
        return spec_nd(_pick(mesh, shape[-2], fs), None)
    if path.endswith(".W2"):
        return spec_nd(None, _pick(mesh, shape[-1], mdl))
    return P(*([None] * nd))


def _path_str(key_path) -> str:
    parts = []
    for k in key_path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return ".".join(parts)


def params_shardings(mesh: Mesh, params_shapes: PyTree,
                     adapter: bool = False, policy: str = "2d") -> PyTree:
    """NamedShardings for a params(-shaped) pytree. ``params_shapes`` may hold
    arrays or ShapeDtypeStructs."""
    rule = _adapter_spec if adapter else _param_spec

    def one(key_path, leaf):
        spec = rule(mesh, _path_str(key_path), tuple(leaf.shape), policy)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shapes)


# ---------------------------------------------------------------------------
# batch / cache / delta shardings
# ---------------------------------------------------------------------------

def batch_axes(mesh: Mesh, policy: str = "2d") -> tuple[str, ...]:
    names = ("pod", "data", "model") if policy == "dp" else ("pod", "data")
    return tuple(a for a in names if a in mesh.axis_names)


def batch_shardings(mesh: Mesh, specs: PyTree, policy: str = "2d") -> PyTree:
    ba = batch_axes(mesh, policy)
    nb = 1
    for a in ba:
        nb *= mesh.shape[a]

    def one(leaf):
        shape = leaf.shape
        first = ba if shape and _div(shape[0], nb) else None
        rest = [None] * (len(shape) - 1)
        return NamedSharding(mesh, P(first, *rest))

    return jax.tree.map(one, specs)


def cache_shardings(mesh: Mesh, cache_specs: PyTree) -> PyTree:
    """KV caches (L, B, S, K, dh) / ssm states (L, B, H, P, N) / conv states.

    Rule: shard B over batch axes when divisible; otherwise shard the longest
    remaining dim (sequence for KV, heads for SSM) over model (+ data if batch
    could not be used) — sequence-parallel decode."""
    ba = batch_axes(mesh)
    nb = 1
    for a in ba:
        nb *= mesh.shape[a]
    nm = _axis(mesh, "model")

    def one(leaf):
        shape = leaf.shape
        nd = len(shape)
        spec: list = [None] * nd
        used_batch = False
        if nd >= 2 and _div(shape[1], nb):
            spec[1] = ba
            used_batch = True
        # find the best dim to put "model" on: prefer dim2 (seq/heads axis)
        for i in (2, 3, 4):
            if i < nd - 0 and spec[i] is None:
                if not used_batch and _div(shape[i], nm * nb):
                    spec[i] = tuple(list(ba) + ["model"])
                    break
                if _div(shape[i], nm):
                    spec[i] = "model"
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, cache_specs)


def delta_shardings(mesh: Mesh, delta_specs: PyTree) -> PyTree:
    """Mode-A deltas (L?, B, S, d_out): batch over (pod,data), d_out over model."""
    ba = batch_axes(mesh)
    nb = 1
    for a in ba:
        nb *= mesh.shape[a]
    nm = _axis(mesh, "model")

    def one(leaf):
        shape = leaf.shape
        nd = len(shape)
        spec: list = [None] * nd
        b_axis = nd - 3
        if _div(shape[b_axis], nb):
            spec[b_axis] = ba
        if _div(shape[-1], nm):
            spec[-1] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, delta_specs)


def replicated(mesh: Mesh, tree: PyTree) -> PyTree:
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, P(*([None] * len(leaf.shape)))), tree)
