"""Gradient Learning (GL): the paper's core algorithm, as composable JAX.

Two equivalent executions of the same math (Prop 1), tested to agree bit-for-bit:

- **Mode A — faithful_offload** (paper Alg. 1): the server step runs forward +
  backward *w.r.t. injected deltas only*, exporting adaptation data
  ``{tap: (x_m, grad_h_m)}``. ``fit_grads`` then evaluates the gradient of the
  quadratic fit loss (Eq. 6) anywhere — no access to the base model needed.

- **Mode B — fused_fit** (beyond-paper): the fit-gradient contraction happens
  inside the same XLA program via ``jax.grad`` w.r.t. the adapter vars, which by
  Prop 1 yields the identical numbers while never exporting (B,S,d) tensors.

Also here: the classic baselines the paper compares against (LoRA == Mode B with
on-device optimizer; full FT) and tap selection.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ColaConfig, ModelConfig
from repro.core import adapters as adapters_lib
from repro.core import taps as taps_lib
from repro.core.taps import ColaSpec
from repro.kernels import ops as kernel_ops
from repro.models import model as model_lib

Array = jax.Array


# ---------------------------------------------------------------------------
# tap selection
# ---------------------------------------------------------------------------

def select_taps(cfg: ModelConfig, taps: str) -> tuple[str, ...]:
    sites = model_lib.tap_sites(cfg)
    if taps == "qv":
        names = [n for n in sites
                 if n.endswith("attn.q") or n.endswith("attn.v")]
        if not names:   # attention-free (mamba2): tap the SSM projections
            names = [n for n in sites if ".ssm." in n]
    elif taps == "all_attn":
        names = [n for n in sites if ".attn." in n]
    elif taps == "mlp":
        names = [n for n in sites if ".mlp." in n]
    elif taps == "ssm":
        names = [n for n in sites if ".ssm." in n]
    elif taps == "all":
        names = list(sites)
    else:
        names = [n for n in sites if n in taps.split(",")]
        if not names:
            raise ValueError(f"no taps matched {taps!r}")
    return tuple(sorted(names))


def make_spec(cfg: ModelConfig, cc: ColaConfig) -> ColaSpec:
    taps = select_taps(cfg, cc.taps)
    if cc.mode in ("ft", "frozen"):
        return taps_lib.make_spec()
    collect = inject = ()
    families = {t: cc.family for t in taps}
    if cc.mode == "faithful_offload":
        collect, inject = taps, taps
        if cc.merged:
            # merged server pass: adapters folded into the base weights, only
            # injection+collection live in the graph (zero adapter FLOPs).
            families = {}
    return taps_lib.ColaSpec(families=tuple(sorted(families.items())),
                             collect=collect, inject=inject, scale=cc.scale,
                             rank=cc.rank, hidden=cc.hidden)


def init_adapters(cfg: ModelConfig, cc: ColaConfig, key: Array,
                  dtype=jnp.float32) -> dict:
    taps = select_taps(cfg, cc.taps)
    sites = model_lib.tap_sites(cfg)
    spec = taps_lib.make_spec(family=cc.family, taps=taps, rank=cc.rank,
                              hidden=cc.hidden, scale=cc.scale)
    return taps_lib.init_adapter_vars(spec, sites, key, dtype=dtype)


# ---------------------------------------------------------------------------
# Mode A: server step (grad of hidden representations only) + offloaded fit
# ---------------------------------------------------------------------------

def zero_deltas(cfg: ModelConfig, spec: ColaSpec, batch: int, seq: int,
                dtype=jnp.float32) -> dict:
    sites = model_lib.tap_sites(cfg)
    return {name: jnp.zeros(model_lib.delta_shape(cfg, sites[name], batch, seq),
                            dtype)
            for name in spec.inject}


def server_step_a(cfg: ModelConfig, spec: ColaSpec, params: dict,
                  adapters: dict, batch: dict):
    """Paper Alg. 1 lines 4-9: one forward + backward on the base device,
    producing loss and adaptation data {tap: (x_m, grad_h_m)}.

    ``params`` should already be merged if running in merged mode (then
    ``spec.families`` is empty and adapters are not applied in-graph).
    """
    tok = batch.get("tokens", batch.get("embeds"))
    bsz, seq = tok.shape[0], tok.shape[1]
    deltas0 = zero_deltas(cfg, spec, bsz, seq)

    def f(deltas):
        loss, aux = model_lib.loss_fn(cfg, params, batch, spec,
                                      {"adapters": adapters, "deltas": deltas})
        return loss, aux

    (loss, aux), grads = jax.value_and_grad(f, has_aux=True)(deltas0)
    collected = dict(aux["collected"])
    collected.update(aux.get("collected_shared", {}))
    data = {t: (collected[t], grads[t]) for t in spec.inject}
    return loss, data, aux


def fit_grads(spec: ColaSpec, adapters: dict, data: dict[str, tuple]) -> dict:
    """Gradient of the quadratic fit loss (Eq. 6) evaluated at w_t.

    By Prop 1:  dl/dw|_{w_t} = (dg/dw)^T grad_h  — a VJP of the adapter alone.
    Works for any adapter family; for lowrank it routes through the fused
    cola_fit kernel. ``data``: {tap: (x, grad_h)} with x (L?, B, S, d_in).
    Returns {tap: grad_pytree} matching ``adapters``.
    """
    out = {}
    fam_map = spec.family_map
    for tap, (x, gh) in data.items():
        fam = fam_map[tap]
        w = adapters[tap]
        stacked = jax.tree.leaves(w)[0].ndim > 2  # leading layer axis present?
        ghs = (gh * spec.scale).astype(jnp.float32)
        xs = x.astype(jnp.float32)

        def one(w_l, x_l, g_l):
            xr = x_l.reshape(-1, x_l.shape[-1])
            gr = g_l.reshape(-1, g_l.shape[-1])
            if fam == "lowrank":
                dA, dB = kernel_ops.cola_fit_lowrank(xr, gr, w_l["A"], w_l["B"])
                return {"A": dA, "B": dB}
            _, vjp = jax.vjp(lambda ww: adapters_lib.apply(fam, ww, xr), w_l)
            (g,) = vjp(gr)
            return g

        if stacked and xs.ndim == 4:
            out[tap] = jax.vmap(one)(w, xs, ghs)
        elif not stacked and xs.ndim == 4:
            # shared site: one adapter, per-invocation data — grads sum.
            g = jax.vmap(lambda x_l, g_l: one(w, x_l, g_l))(xs, ghs)
            out[tap] = jax.tree.map(lambda a: jnp.sum(a, axis=0), g)
        else:
            out[tap] = one(w, xs, ghs)
    return out


def fit_loss(spec: ColaSpec, adapters: dict, data: dict[str, tuple],
             adapters_t: dict) -> Array:
    """The literal quadratic objective of Eq. 6 (used by tests / multi-step
    local fitting): 1/2 || g_w(x) - (dh_t - grad_h) ||^2 summed over taps.
    ``adapters_t`` holds the w_t snapshot that defines dh_t."""
    total = jnp.zeros((), jnp.float32)
    fam_map = spec.family_map
    for tap, (x, gh) in data.items():
        fam = fam_map[tap]
        xr = x.astype(jnp.float32)
        ghr = (gh * spec.scale).astype(jnp.float32)

        def g_apply(w, xx):
            return adapters_lib.apply(fam, w, xx)

        stacked = jax.tree.leaves(adapters[tap])[0].ndim > 2
        if stacked and xr.ndim == 4:
            dh_t = jax.vmap(g_apply)(adapters_t[tap], xr)
            pred = jax.vmap(g_apply)(adapters[tap], xr)
        elif not stacked and xr.ndim == 4:
            dh_t = jax.vmap(lambda xx: g_apply(adapters_t[tap], xx))(xr)
            pred = jax.vmap(lambda xx: g_apply(adapters[tap], xx))(xr)
        else:
            dh_t = g_apply(adapters_t[tap], xr)
            pred = g_apply(adapters[tap], xr)
        target = dh_t - ghr
        total = total + 0.5 * jnp.sum((pred - target) ** 2)
    return total


# ---------------------------------------------------------------------------
# Mode B: fused fit (and the LoRA baseline, which shares its math)
# ---------------------------------------------------------------------------

def train_step_b(cfg: ModelConfig, spec: ColaSpec, params: dict,
                 adapters: dict, batch: dict):
    """Loss + adapter gradients in one program. Base params are *not*
    differentiated (frozen). Returns (loss, grads, aux)."""

    def f(ad):
        return model_lib.loss_fn(cfg, params, batch, spec, {"adapters": ad})

    (loss, aux), grads = jax.value_and_grad(f, has_aux=True)(adapters)
    return loss, grads, aux


def train_step_ft(cfg: ModelConfig, params: dict, batch: dict):
    """Full fine-tuning baseline: gradients of every base parameter."""

    def f(p):
        return model_lib.loss_fn(cfg, p, batch)

    (loss, aux), grads = jax.value_and_grad(f, has_aux=True)(params)
    return loss, grads, aux
