"""Auxiliary models g_w (the paper's model-agnostic adapters).

Families
--------
- ``lowrank``  : g(x) = (x @ A) @ B           (== LoRA; mergeable, Prop 2)
- ``linear``   : g(x) = x @ W                 (== full delta-W; mergeable, Prop 2;
                 recovers full fine-tuning / training-from-scratch, paper §C.3)
- ``mlp``      : g(x) = relu(x @ W1 + b1) @ W2 (NOT mergeable — nonlinear in x)

Adapters are plain pytrees; a family is identified by the static string carried in
``ColaSpec`` (see ``repro.core.taps``). Everything here is shape-polymorphic so the
same code runs per-layer or stacked over a leading layer axis via vmap/scan.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = Any

MERGEABLE = {"lowrank": True, "linear": True, "mlp": False,
             "multi_lowrank": False}
FAMILIES = tuple(MERGEABLE)


def init(family: str, key: jax.Array, d_in: int, d_out: int, *,
         rank: int = 8, hidden: int = 128, dtype=jnp.float32) -> Params:
    """Initialise adapter params so that g(x) == 0 at t=0 (paper Alg. 1 init)."""
    if family == "lowrank":
        # LoRA init: A ~ N(0, 1/r) (kaiming-ish), B = 0  -> g(x)=0.
        a = jax.random.normal(key, (d_in, rank), dtype) / jnp.sqrt(jnp.asarray(rank, dtype))
        return {"A": a, "B": jnp.zeros((rank, d_out), dtype)}
    if family == "linear":
        return {"W": jnp.zeros((d_in, d_out), dtype)}
    if family == "mlp":
        w1 = jax.random.normal(key, (d_in, hidden), dtype) / jnp.sqrt(jnp.asarray(d_in, dtype))
        return {
            "W1": w1,
            "b1": jnp.zeros((hidden,), dtype),
            "W2": jnp.zeros((hidden, d_out), dtype),
        }
    raise ValueError(f"unknown adapter family: {family!r}")


def apply(family: str, w: Params, x: jax.Array) -> jax.Array:
    """g_w(x). x: (..., d_in) -> (..., d_out). Computes in x.dtype."""
    if family == "lowrank":
        return (x @ w["A"].astype(x.dtype)) @ w["B"].astype(x.dtype)
    if family == "linear":
        return x @ w["W"].astype(x.dtype)
    if family == "mlp":
        h = jax.nn.relu(x @ w["W1"].astype(x.dtype) + w["b1"].astype(x.dtype))
        return h @ w["W2"].astype(x.dtype)
    if family == "multi_lowrank":
        # FTaaS serving: per-request adapters in one batch (multi-LoRA).
        # w: {"A": (U, d_in, r), "B": (U, r, d_out), "idx": (B,)}; x: (B, S, d).
        # int8-stored banks instead carry {"A_q", "A_scale", "B_q", "B_scale"}
        # and dequantise on load (never a f32 copy of the bank).
        from repro.kernels import ops as kernel_ops
        Bz, S = x.shape[0], x.shape[1]
        flat = x.reshape(Bz * S, x.shape[-1])
        idx = jnp.repeat(w["idx"].astype(jnp.int32), S)
        if "A_q" in w:
            y = kernel_ops.multi_lora_q8(flat, w["A_q"], w["A_scale"],
                                         w["B_q"], w["B_scale"], idx)
        else:
            y = kernel_ops.multi_lora(flat, w["A"], w["B"], idx)
        return y.reshape(Bz, S, -1)
    raise ValueError(f"unknown adapter family: {family!r}")


def merge_delta(family: str, w: Params, scale: float) -> jax.Array:
    """Return the delta-W such that base_W + delta == merged weights (Prop 2).

    Only defined for families linear in x. Supports stacked leading layer axes.
    """
    if family == "lowrank":
        return scale * (w["A"] @ w["B"])
    if family == "linear":
        return scale * w["W"]
    raise ValueError(f"adapter family {family!r} is not mergeable (Prop 2: "
                     "merging requires g linear in its input)")


def is_mergeable(family: str) -> bool:
    return MERGEABLE[family]


def shapes(family: str, d_in: int, d_out: int, *, rank: int = 8,
           hidden: int = 128) -> dict[str, tuple[int, ...]]:
    if family == "lowrank":
        return {"A": (d_in, rank), "B": (rank, d_out)}
    if family == "linear":
        return {"W": (d_in, d_out)}
    if family == "mlp":
        return {"W1": (d_in, hidden), "b1": (hidden,), "W2": (hidden, d_out)}
    raise ValueError(family)


def param_count(family: str, d_in: int, d_out: int, *, rank: int = 8,
                hidden: int = 128) -> int:
    import numpy as np
    return sum(int(np.prod(s)) for s in shapes(
        family, d_in, d_out, rank=rank, hidden=hidden).values())
