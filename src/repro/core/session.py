"""Single-host ColA training session: ties together the server step, the
offloader, parameter merging and the baselines — the reference runtime used by
examples, benchmarks and tests. (The pod-scale pjit runtime wraps the same
``gl`` functions with shardings; see repro/distributed.)

Modes (ColaConfig.mode):
- "faithful_offload": paper Alg. 1. Server computes (x_m, grad_h_m); the
  Offloader fits adapters off-device every I batches. ``merged=True`` folds
  adapters into the base weights for the server pass (zero adapter FLOPs).
- "fused_fit": beyond-paper Mode B. Adapter grads computed in-graph (Prop 1
  equality), optimizer still lives off-device with interval-I accumulation.
- "lora": classic PEFT baseline — same gradients, on-device optimizer.
- "ft": full fine-tuning baseline.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ColaConfig, ModelConfig
from repro.core import gl, merge
from repro.core import taps as taps_lib
from repro.core.channel import OffloadChannel
from repro.core.offload import Offloader
from repro.models import model as model_lib
from repro.optim import optimizers as optim_lib
from repro.telemetry import NULL_CONTEXT

Array = jax.Array


class ColaSession:
    def __init__(self, cfg: ModelConfig, cc: ColaConfig, params: dict,
                 key: Array, optimizer=None, lr=1e-3, offload_device=None,
                 injector=None, policy=None, telemetry=None):
        self.tm = telemetry if telemetry else None
        self.cfg, self.cc = cfg, cc
        self.base_params = params
        self.optimizer = optimizer or optim_lib.adamw(lr)
        self.server_spec = gl.make_spec(cfg, cc)
        taps = gl.select_taps(cfg, cc.taps) if cc.mode != "ft" else ()
        self.adapter_spec = taps_lib.make_spec(
            family=cc.family, taps=taps, rank=cc.rank, hidden=cc.hidden,
            scale=cc.scale)
        self.step_count = 0

        if cc.mode == "ft":
            self.opt_state = self.optimizer.init(params)
            self._step = jax.jit(self._ft_step)
            return

        self.adapters = gl.init_adapters(cfg, cc, key)
        if cc.mode in ("faithful_offload", "fused_fit"):
            self.offloader = Offloader(self.adapter_spec, self.adapters,
                                       self.optimizer, interval=cc.interval,
                                       compress=cc.compress,
                                       device=offload_device)
            # Mode A ships payloads over the (possibly unreliable) offload
            # transport; the channel adds retry/validation/versioning and is a
            # pure pass-through when no faults are injected.
            self.channel = OffloadChannel(self.offloader, user=0,
                                          injector=injector, policy=policy,
                                          telemetry=self.tm)
        else:  # lora
            self.opt_state = self.optimizer.init(self.adapters)

        if cc.mode == "faithful_offload":
            self._server = jax.jit(functools.partial(
                gl.server_step_a, cfg, self.server_spec))
        elif cc.mode in ("fused_fit", "lora"):
            self._train_b = jax.jit(functools.partial(
                gl.train_step_b, cfg, self.server_spec))

        self._grad_accum = None
        self._merged_cache: dict | None = None

    # ------------------------------------------------------------------
    def _offload_span(self, ch):
        if self.tm is None:
            return NULL_CONTEXT
        return self.tm.span("session.offload_round", cat="offload", tid=1,
                            user=ch.user, seq=ch._seq)

    # ------------------------------------------------------------------
    def _effective_params(self) -> dict:
        if self.cc.mode == "faithful_offload" and self.cc.merged:
            if self._merged_cache is None:
                self._merged_cache = merge.merged_params(
                    self.cfg, self.base_params, self.adapter_spec.family_map,
                    self.adapters, self.cc.scale)
            return self._merged_cache
        return self.base_params

    def _ft_step(self, params, opt_state, batch):
        loss, grads, _ = gl.train_step_ft(self.cfg, params, batch)
        updates, opt_state = self.optimizer.update(grads, opt_state, params)
        return loss, optim_lib.apply_updates(params, updates), opt_state

    # ------------------------------------------------------------------
    def step(self, batch: dict) -> float:
        self.step_count += 1
        cc = self.cc
        if cc.mode == "ft":
            loss, self.base_params, self.opt_state = self._step(
                self.base_params, self.opt_state, batch)
            return float(loss)

        if cc.mode == "faithful_offload":
            params = self._effective_params()
            adapters_in = ({} if cc.merged else self.adapters)
            loss, data, _ = self._server(params, adapters_in, batch)
            # one offload round = push + fit; the channel's own push/fit
            # spans nest inside, carrying the transport seq ids
            with self._offload_span(self.channel):
                self.channel.push(data)
                new = self.channel.fit_round()
            if new is not None:
                self.adapters = new
                self._merged_cache = None   # re-merge from pristine base
            return float(loss)

        if cc.mode == "fused_fit":
            loss, grads, _ = self._train_b(self.base_params, self.adapters, batch)
            # Mode B ships only adapter-gradient-sized tensors; the offload
            # device owns optimizer state and interval accumulation.
            if self._grad_accum is None:
                self._grad_accum = grads
            else:
                self._grad_accum = jax.tree.map(jnp.add, self._grad_accum, grads)
            if self.step_count % cc.interval == 0:
                g = jax.tree.map(lambda a: a / cc.interval, self._grad_accum)
                g = jax.device_put(g, self.offloader.device)
                updates, self.offloader.opt_state = self.optimizer.update(
                    g, self.offloader.opt_state, self.offloader.adapters)
                self.offloader.adapters = optim_lib.apply_updates(
                    self.offloader.adapters, updates)
                self.adapters = self.offloader.adapters
                self._grad_accum = None
            return float(loss)

        # lora baseline: on-device optimizer
        loss, grads, _ = self._train_b(self.base_params, self.adapters, batch)
        updates, self.opt_state = self.optimizer.update(
            grads, self.opt_state, self.adapters)
        self.adapters = optim_lib.apply_updates(self.adapters, updates)
        return float(loss)

    # ------------------------------------------------------------------
    def reset_channels(self) -> None:
        """Watchdog recovery hook: drop in-flight offload state, restore the
        last-good bank, lift quarantine (no-op for channel-less modes)."""
        ch = getattr(self, "channel", None)
        if ch is not None:
            ch.reset()
            self.adapters = ch.adapters
            self._merged_cache = None

    def channel_health(self) -> dict:
        ch = getattr(self, "channel", None)
        return {0: ch.health()} if ch is not None else {}

    # ------------------------------------------------------------------
    def inference_params(self) -> dict:
        """Merged params for serving (PEFT merge-for-inference)."""
        if self.cc.mode == "ft":
            return self.base_params
        fams = self.adapter_spec.family_map
        mergeable = {t: w for t, w in self.adapters.items()
                     if fams[t] in ("lowrank", "linear")}
        if len(mergeable) != len(self.adapters):
            return self.base_params   # non-mergeable families stay unmerged
        return merge.merged_params(self.cfg, self.base_params, fams,
                                   mergeable, self.cc.scale)

    def eval_loss(self, batch: dict) -> float:
        params = self._effective_params()
        if self.cc.mode == "faithful_offload" and self.cc.merged:
            loss, _ = model_lib.loss_fn(self.cfg, params, batch)
        elif self.cc.mode == "ft":
            loss, _ = model_lib.loss_fn(self.cfg, params, batch)
        else:
            loss, _ = model_lib.loss_fn(
                self.cfg, params, batch, self.server_spec.with_adapters_only(),
                {"adapters": self.adapters})
        return float(loss)
