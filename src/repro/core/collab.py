"""K-user collaboration (paper §3.2 FTaaS, Table 4).

Setups (paper Table 4):
- "joint":  one shared adapter bank trained on all users' data.
- "alone":  each user trains their own bank on their own rows (no merging
            during training); merging the K banks only at inference degrades —
            the paper's observation, reproduced in benchmarks/collaboration.py.
- "collab": all K banks merged into the base weights during training; each
            user's rows update only their own bank (per-user gradient
            isolation via row masking — exact, since the fit VJP is linear in
            grad_h).

The server cost is constant in K: one merged forward/backward per batch
(paper Table 1, ColA merged row).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ColaConfig, ModelConfig
from repro.core import gl, merge
from repro.core import taps as taps_lib
from repro.core.offload import Offloader
from repro.models import model as model_lib
from repro.optim import optimizers as optim_lib

Array = jax.Array


def mask_user_rows(data: dict[str, tuple], user_ids: Array, k: int) -> dict:
    """Zero grad_h on rows not belonging to user k. Because the fit gradient is
    linear in grad_h, fitting on masked data gives exactly user k's gradient."""
    out = {}
    for tap, (x, gh) in data.items():
        b_axis = gh.ndim - 3          # (L?, B, S, d)
        shape = [1] * gh.ndim
        shape[b_axis] = gh.shape[b_axis]
        m = (user_ids == k).astype(gh.dtype).reshape(shape)
        out[tap] = (x, gh * m)
    return out


class CollabSession:
    """K users fine-tuning one base model collaboratively (merged training)."""

    def __init__(self, cfg: ModelConfig, cc: ColaConfig, params: dict,
                 key: Array, optimizer=None, lr=1e-3,
                 families: list[str] | None = None):
        assert cc.mode == "faithful_offload" and cc.merged, \
            "collaboration uses merged faithful-offload training (Alg. 1)"
        self.cfg, self.cc = cfg, cc
        self.base_params = params
        self.K = cc.users
        taps = gl.select_taps(cfg, cc.taps)
        # users may choose different adapter families (paper: LowRank-Linear)
        fams = families or [cc.family] * self.K
        assert len(fams) == self.K
        self.user_specs = [
            taps_lib.make_spec(family=f, taps=taps, rank=cc.rank,
                               hidden=cc.hidden, scale=cc.scale)
            for f in fams]
        self.server_spec = gl.make_spec(cfg, cc)   # inject/collect only
        optimizer = optimizer or optim_lib.adamw(lr)
        sites = model_lib.tap_sites(cfg)
        self.offloaders = []
        for k in range(self.K):
            ad = taps_lib.init_adapter_vars(
                self.user_specs[k], sites, jax.random.fold_in(key, k))
            self.offloaders.append(Offloader(
                self.user_specs[k], ad, optimizer, interval=cc.interval,
                compress=cc.compress))
        self._server = jax.jit(functools.partial(
            gl.server_step_a, cfg, self.server_spec))
        self._merged_cache = None
        self.step_count = 0

    # ------------------------------------------------------------------
    def merged_model(self) -> dict:
        if self._merged_cache is None:
            p = self.base_params
            for k in range(self.K):
                p = merge.merged_params(self.cfg, p,
                                        self.user_specs[k].family_map,
                                        self.offloaders[k].adapters,
                                        self.cc.scale)
            self._merged_cache = p
        return self._merged_cache

    def train_step(self, batch: dict, user_ids: Array) -> float:
        """One FTaaS iteration: merged server pass + per-user offloaded fits."""
        self.step_count += 1
        params = self.merged_model()
        loss, data, _ = self._server(params, {}, batch)
        updated = False
        for k in range(self.K):
            self.offloaders[k].push(mask_user_rows(data, user_ids, k))
            if self.offloaders[k].maybe_fit() is not None:
                updated = True
        if updated:
            self._merged_cache = None
        return float(loss)
