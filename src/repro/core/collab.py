"""K-user collaboration (paper §3.2 FTaaS, Table 4).

Setups (paper Table 4):
- "joint":  one shared adapter bank trained on all users' data.
- "alone":  each user trains their own bank on their own rows (no merging
            during training); merging the K banks only at inference degrades —
            the paper's observation, reproduced in benchmarks/collaboration.py.
- "collab": all K banks merged into the base weights during training; each
            user's rows update only their own bank (per-user gradient
            isolation via row masking — exact, since the fit VJP is linear in
            grad_h).

The server cost is constant in K: one merged forward/backward per batch
(paper Table 1, ColA merged row).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ColaConfig, ModelConfig
from repro.core import gl, merge
from repro.core import taps as taps_lib
from repro.core.channel import OffloadChannel
from repro.core.offload import Offloader
from repro.models import model as model_lib
from repro.optim import optimizers as optim_lib
from repro.telemetry import NULL_CONTEXT

Array = jax.Array


def mask_user_rows(data: dict[str, tuple], user_ids: Array, k: int) -> dict:
    """Zero grad_h on rows not belonging to user k. Because the fit gradient is
    linear in grad_h, fitting on masked data gives exactly user k's gradient."""
    out = {}
    for tap, (x, gh) in data.items():
        b_axis = gh.ndim - 3          # (L?, B, S, d)
        shape = [1] * gh.ndim
        shape[b_axis] = gh.shape[b_axis]
        m = (user_ids == k).astype(gh.dtype).reshape(shape)
        out[tap] = (x, gh * m)
    return out


class CollabSession:
    """K users fine-tuning one base model collaboratively (merged training)."""

    def __init__(self, cfg: ModelConfig, cc: ColaConfig, params: dict,
                 key: Array, optimizer=None, lr=1e-3,
                 families: list[str] | None = None,
                 injector=None, policy=None, max_update_norm: float = 1e4,
                 quarantine_after: int = 2, telemetry=None):
        assert cc.mode == "faithful_offload" and cc.merged, \
            "collaboration uses merged faithful-offload training (Alg. 1)"
        self.tm = telemetry if telemetry else None
        self.cfg, self.cc = cfg, cc
        self.base_params = params
        self.K = cc.users
        taps = gl.select_taps(cfg, cc.taps)
        # users may choose different adapter families (paper: LowRank-Linear)
        fams = families or [cc.family] * self.K
        assert len(fams) == self.K
        self.user_specs = [
            taps_lib.make_spec(family=f, taps=taps, rank=cc.rank,
                               hidden=cc.hidden, scale=cc.scale)
            for f in fams]
        self.server_spec = gl.make_spec(cfg, cc)   # inject/collect only
        optimizer = optimizer or optim_lib.adamw(lr)
        sites = model_lib.tap_sites(cfg)
        self.offloaders = []
        self.channels: list[OffloadChannel] = []
        for k in range(self.K):
            ad = taps_lib.init_adapter_vars(
                self.user_specs[k], sites, jax.random.fold_in(key, k))
            off = Offloader(self.user_specs[k], ad, optimizer,
                            interval=cc.interval, compress=cc.compress)
            self.offloaders.append(off)
            # each user ships over their own fault domain: one channel per
            # offloader, so a faulted user degrades alone (quarantine +
            # rollback) while the round continues with the survivors.
            self.channels.append(OffloadChannel(
                off, user=k, injector=injector, policy=policy,
                max_update_norm=max_update_norm,
                quarantine_after=quarantine_after, telemetry=self.tm))
        self._server = jax.jit(functools.partial(
            gl.server_step_a, cfg, self.server_spec))
        self._merged_cache = None
        self.step_count = 0

    # ------------------------------------------------------------------
    def merged_model(self) -> dict:
        if self._merged_cache is None:
            p = self.base_params
            for k in range(self.K):
                p = merge.merged_params(self.cfg, p,
                                        self.user_specs[k].family_map,
                                        self.offloaders[k].adapters,
                                        self.cc.scale)
            self._merged_cache = p
        return self._merged_cache

    def train_step(self, batch: dict, user_ids: Array) -> float:
        """One FTaaS iteration: merged server pass + per-user offloaded fits.

        Every user's push/fit goes through their `OffloadChannel`: transit
        faults are retried, invalid updates are rolled back, and a user whose
        rounds keep failing is quarantined — the round always completes with
        the surviving users, and the merged model only ever folds in
        validated (last-good) banks.
        """
        self.step_count += 1
        params = self.merged_model()
        loss, data, _ = self._server(params, {}, batch)
        updated = False
        for k in range(self.K):
            ch = self.channels[k]
            # per-user offload-round span; the channel's push/fit spans
            # (carrying transport seq ids) nest inside it
            with self._offload_span(ch):
                ch.push(mask_user_rows(data, user_ids, k))
                if ch.fit_round() is not None:
                    updated = True
        if updated:
            self._merged_cache = None
        return float(loss)

    def _offload_span(self, ch):
        if self.tm is None:
            return NULL_CONTEXT
        return self.tm.span("session.offload_round", cat="offload", tid=1,
                            user=ch.user, seq=ch._seq)

    # -- fault-tolerance surface ----------------------------------------
    def bank_versions(self) -> list[int]:
        return [ch.version for ch in self.channels]

    def channel_health(self) -> dict[int, dict]:
        return {k: ch.health() for k, ch in enumerate(self.channels)}

    def reset_channels(self) -> None:
        """Watchdog recovery hook: reset every user's channel (drop in-flight
        buffers, restore last-good banks, lift quarantine)."""
        for ch in self.channels:
            ch.reset()
        self._merged_cache = None
