"""Tap machinery: the functional replacement for the paper's PyTorch hooks.

A *tap* is a named Dense site ``y = x @ W`` where ColA may
  (1) apply an adapter:      y += scale * g_w(x)          (unmerged mode)
  (2) inject a delta:        y += delta                   (grad-extraction: d/d delta == grad of h-hat)
  (3) record the hidden input x (the paper's "gather hidden input of auxiliary
      models from forward pass", Alg. 1 line 5).

``ColaSpec`` is static (hashable) — carried through jit as a static arg.
``cola_vars`` is the matching pytree: {"adapters": {tap: w}, "deltas": {tap: arr}}.

Tap naming convention: taps inside the scanned layer stack are named
``layers.<site>`` and their vars carry a leading (L,) axis which the model's scan
slices per layer. Taps outside the stack (shared blocks, heads) use other prefixes
and are unstacked.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
import jax.numpy as jnp

from repro.core import adapters as adapters_lib

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TapSite:
    """Static description of one tappable Dense site."""
    name: str          # e.g. "layers.attn.q"
    d_in: int
    d_out: int
    stacked: int = 0   # number of stacked layers (0 = unstacked)


@dataclasses.dataclass(frozen=True)
class ColaSpec:
    """Static ColA call configuration (hashable; pass as static argument)."""
    families: tuple[tuple[str, str], ...] = ()  # (tap_name, family)
    collect: tuple[str, ...] = ()               # taps whose hidden input x to record
    inject: tuple[str, ...] = ()                # taps with delta injection
    scale: float = 1.0
    rank: int = 8
    hidden: int = 128

    @property
    def family_map(self) -> dict[str, str]:
        return dict(self.families)

    def tap_names(self) -> tuple[str, ...]:
        seen = dict.fromkeys([n for n, _ in self.families])
        for n in self.collect + self.inject:
            seen.setdefault(n)
        return tuple(seen)

    def with_adapters_only(self) -> "ColaSpec":
        return dataclasses.replace(self, collect=(), inject=())


def make_spec(sites: Mapping[str, TapSite] | None = None, *, family: str | None = None,
              families: Mapping[str, str] | None = None, taps: tuple[str, ...] = (),
              collect: tuple[str, ...] = (), inject: tuple[str, ...] = (),
              scale: float = 1.0, rank: int = 8, hidden: int = 128) -> ColaSpec:
    fam: dict[str, str] = dict(families or {})
    if family is not None:
        for t in taps:
            fam.setdefault(t, family)
    return ColaSpec(families=tuple(sorted(fam.items())), collect=tuple(collect),
                    inject=tuple(inject), scale=scale, rank=rank, hidden=hidden)


def init_adapter_vars(spec: ColaSpec, sites: Mapping[str, TapSite], key: Array,
                      dtype=jnp.float32) -> dict:
    """Initialise {"adapters": {tap: w}} for every adapted tap in spec.

    Stacked sites get a leading (L,) axis on every adapter leaf.
    """
    out: dict[str, Any] = {}
    for i, (name, family) in enumerate(spec.families):
        site = sites[name]
        k = jax.random.fold_in(key, i)
        if site.stacked:
            ks = jax.random.split(k, site.stacked)
            w = jax.vmap(lambda kk: adapters_lib.init(
                family, kk, site.d_in, site.d_out, rank=spec.rank,
                hidden=spec.hidden, dtype=dtype))(ks)
        else:
            w = adapters_lib.init(family, k, site.d_in, site.d_out,
                                  rank=spec.rank, hidden=spec.hidden, dtype=dtype)
        out[name] = w
    return out


def zero_delta_vars(spec: ColaSpec, sites: Mapping[str, TapSite],
                    batch_shape: tuple[int, ...], dtype=jnp.float32) -> dict:
    """Zero deltas {"tap": (L?, *batch_shape, d_out)} for grad extraction (Mode A)."""
    out = {}
    for name in spec.inject:
        site = sites[name]
        shape = batch_shape + (site.d_out,)
        if site.stacked:
            shape = (site.stacked,) + shape
        out[name] = jnp.zeros(shape, dtype)
    return out


def slice_layer_vars(cola_vars: dict | None, scanned_prefix: str = "layers.") -> tuple[dict, dict]:
    """Split cola vars into (scanned, unstacked) parts by tap-name prefix."""
    if not cola_vars:
        return {}, {}
    scanned = {k: v for k, v in cola_vars.items() if k.startswith(scanned_prefix)}
    rest = {k: v for k, v in cola_vars.items() if not k.startswith(scanned_prefix)}
    return scanned, rest


def apply_tap(spec: ColaSpec | None, name: str, x: Array, y: Array,
              adapters: Mapping[str, Any] | None = None,
              deltas: Mapping[str, Any] | None = None) -> tuple[Array, dict[str, Array]]:
    """Apply adapter/injection at a tap; returns (y', collected_aux).

    ``adapters``/``deltas`` hold the per-call (already layer-sliced) vars.
    """
    if spec is None:
        return y, {}
    aux: dict[str, Array] = {}
    if name in spec.collect:
        aux[name] = x
    fam = spec.family_map.get(name)
    if fam is not None and adapters and name in adapters:
        g = adapters_lib.apply(fam, adapters[name], x)
        y = y + jnp.asarray(spec.scale, y.dtype) * g.astype(y.dtype)
    if deltas and name in deltas and name in spec.inject:
        y = y + deltas[name].astype(y.dtype)
    return y, aux
