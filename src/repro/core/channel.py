"""Fault-tolerant offload channel: the reliability layer between the server
and one user's low-cost fitting device (paper Fig. 1, FTaaS deployment).

`OffloadChannel` wraps an `Offloader` behind an (optional) `FaultInjector` and
a `RetryPolicy` and enforces four invariants the rest of the stack relies on:

1. **Exactly-once payload delivery.** Every pushed payload carries a sequence
   id and a checksum; duplicates are discarded, corrupt/NaN copies are nacked
   and re-sent with exponential backoff, and payloads whose retries are
   exhausted land in the dead-letter queue instead of a buffer.
2. **Versioned adapter banks.** Every committed fit bumps ``version``; readers
   (merged training, the serve engine) can hot-swap on version bumps and never
   observe a half-applied update.
3. **Validated commits only.** A returned adapter bank is committed only if
   every leaf is finite and the update norm against the last-good bank is
   bounded; anything else is retried (refit is deterministic) and finally
   rolled back — ``offloader.adapters`` therefore always holds a validated
   bank.
4. **Per-user quarantine.** A user whose fit rounds keep failing is
   quarantined: their bank is frozen at the last-good version and their
   subsequent payloads are refused, so one poisoned user can never perturb a
   healthy peer or take down the round. ``reset()`` (the watchdog recovery
   hook) lifts quarantine after external recovery.
"""
from __future__ import annotations

import time
from typing import Any

import jax
import numpy as np

from repro.runtime.faults import (DeadLetter, Delivery, FaultInjector,
                                  FitTimeout, RetryPolicy, call_with_timeout)
from repro.telemetry import NULL_CONTEXT


def _tree_sums(tree) -> tuple[float, ...]:
    """Per-leaf float64 content sums — the transfer checksum."""
    return tuple(float(np.asarray(jax.device_get(l), np.float64).sum())
                 for l in jax.tree.leaves(tree))


def _tree_finite(tree) -> bool:
    return all(bool(np.isfinite(np.asarray(jax.device_get(l))).all())
               for l in jax.tree.leaves(tree))


def _update_norm(new, old) -> float:
    sq = 0.0
    for a, b in zip(jax.tree.leaves(new), jax.tree.leaves(old)):
        d = (np.asarray(jax.device_get(a), np.float64)
             - np.asarray(jax.device_get(b), np.float64))
        sq += float((d * d).sum())
    return float(np.sqrt(sq))


def _checksums_match(got: tuple[float, ...], want: tuple[float, ...]) -> bool:
    if len(got) != len(want):
        return False
    return all(g == w or abs(g - w) <= 1e-6 * max(1.0, abs(w))
               for g, w in zip(got, want))


class OffloadChannel:
    """Reliable transport + validation around one user's `Offloader`."""

    def __init__(self, offloader, *, user: int = 0,
                 injector: FaultInjector | None = None,
                 policy: RetryPolicy | None = None,
                 max_update_norm: float = 1e4,
                 quarantine_after: int = 2,
                 on_commit=None, telemetry=None):
        self.offloader = offloader
        self.user = user
        self.injector = injector
        self.policy = policy or RetryPolicy()
        self.max_update_norm = max_update_norm
        self.quarantine_after = quarantine_after
        # publication hook: called as on_commit(user, version, adapters)
        # after every validated commit — the push-based counterpart to
        # polling `publish_banks` (e.g. a serving engine's tiered adapter
        # store subscribing to fit results). Only ever sees committed banks.
        self.on_commit = on_commit

        # telemetry is observational: every record/span reads values already
        # computed for the reliability protocol, never perturbs it
        self.tm = telemetry if telemetry else None
        if self.tm:
            self.tm.name_thread(1, "offload")
        # last failure this channel observed (reason string + offending seq),
        # exposed via health() so operators can tell *why* a user degraded
        # without trawling logs
        self.last_error: str | None = None
        self.last_error_seq: int | None = None

        self.version = 0
        self.last_good: dict = offloader.adapters   # validated by construction
        self.quarantined = False
        self.dead_letters: list[DeadLetter] = []
        self._seq = 0
        self._seen: set[int] = set()
        self._fail_streak = 0
        self._rng = np.random.default_rng(np.random.SeedSequence((1337, user)))
        self.health_counters = {
            "pushes": 0, "delivered": 0, "send_retries": 0,
            "dup_discarded": 0, "corrupt_rejected": 0, "nan_rejected": 0,
            "late_deliveries": 0, "late_dropped": 0, "refused_quarantined": 0,
            "dead_letters": 0, "fit_attempts": 0, "fits_committed": 0,
            "fit_timeouts": 0, "fit_errors": 0, "fit_rejected": 0,
            "rollbacks": 0, "backoff_s": 0.0,
        }

    # -- convenience -------------------------------------------------------
    @property
    def adapters(self) -> dict:
        """The user's bank. Invariant: only ever a validated, committed bank."""
        return self.offloader.adapters

    def health(self) -> dict:
        out = dict(self.health_counters)
        out.update(version=self.version, quarantined=self.quarantined,
                   fail_streak=self._fail_streak,
                   dead_letter_count=len(self.dead_letters),
                   last_error=self.last_error,
                   last_error_seq=self.last_error_seq)
        return out

    def health_brief(self) -> dict:
        """Compact health record for periodic logging (TrainLoop's
        metrics.jsonl): the handful of fields that flag a degrading user."""
        h = self.health_counters
        return {"version": self.version, "quarantined": self.quarantined,
                "fail_streak": self._fail_streak,
                "dead_letters": len(self.dead_letters),
                "fits_committed": h["fits_committed"],
                "rollbacks": h["rollbacks"],
                "last_error": self.last_error,
                "last_error_seq": self.last_error_seq}

    # -- telemetry ----------------------------------------------------------
    def _span(self, name: str, **args):
        if self.tm is None:
            return NULL_CONTEXT
        return self.tm.span(name, cat="offload", tid=1, **args)

    def _record(self, kind: str, **fields) -> None:
        if self.tm is not None:
            self.tm.record("user", self.user, kind, **fields)

    def _note_error(self, kind: str, reason: str, seq: int) -> None:
        self.last_error = reason
        self.last_error_seq = seq
        self._record(kind, reason=reason, seq=seq)

    # -- transport: server -> offload device -------------------------------
    def _transmit(self, kind: str, obj) -> list[Delivery]:
        if self.injector is None:
            return [Delivery(obj)]
        return self.injector.transmit(self.user, kind, obj)

    def push(self, data: dict[str, tuple]) -> bool:
        """Ship one batch of adaptation data, retrying transit faults.

        Returns True when exactly one clean copy reached the offload buffers;
        False when the user is quarantined or retries were exhausted (the
        payload is then dead-lettered, not silently lost).
        """
        with self._span("channel.push", user=self.user, seq=self._seq):
            return self._push(data)

    def _push(self, data: dict[str, tuple]) -> bool:
        h = self.health_counters
        h["pushes"] += 1
        if self.quarantined:
            h["refused_quarantined"] += 1
            self._note_error("push_refused", "quarantined", self._seq)
            return False
        seq = self._seq
        self._seq += 1
        want = _tree_sums(data)
        for attempt in range(1, self.policy.max_attempts + 1):
            accepted = False
            for d in self._transmit("payload", data):
                if d.late_ticks > self.policy.timeout_ticks:
                    h["late_dropped"] += 1    # arrives after the resend window
                    continue
                if d.late_ticks:
                    h["late_deliveries"] += 1
                if seq in self._seen:         # duplicate of an acked payload
                    h["dup_discarded"] += 1
                    accepted = True
                    continue
                if not _tree_finite(d.obj):
                    h["nan_rejected"] += 1
                    self._note_error("payload_nack", "non-finite payload", seq)
                    continue
                if not _checksums_match(_tree_sums(d.obj), want):
                    h["corrupt_rejected"] += 1
                    self._note_error("payload_nack",
                                     "payload checksum mismatch", seq)
                    continue
                self._seen.add(seq)
                self.offloader.push(d.obj)
                accepted = True
            if accepted:
                h["delivered"] += 1
                self._record("delivered", seq=seq, attempts=attempt)
                return True
            h["send_retries"] += 1
            h["backoff_s"] += self.policy.wait(attempt, self._rng)
        self.dead_letters.append(DeadLetter(
            self.user, seq, "payload", "send retries exhausted",
            self.policy.max_attempts, data))
        h["dead_letters"] += 1
        self._note_error("dead_letter", "send retries exhausted", seq)
        return False

    # -- fit round: offload device -> server --------------------------------
    def _snapshot(self):
        off = self.offloader
        return (off.adapters, off.opt_state,
                {k: list(v) for k, v in off.buffers.items()}, off._pushes)

    def _restore(self, snap) -> None:
        off = self.offloader
        off.adapters, off.opt_state = snap[0], snap[1]
        off.buffers.clear()
        off.buffers.update({k: list(v) for k, v in snap[2].items()})
        off._pushes = snap[3]

    def _validate_bank(self, bank) -> str | None:
        if not _tree_finite(bank):
            return "non-finite adapter update"
        norm = _update_norm(bank, self.last_good)
        if norm > self.max_update_norm:
            return f"update norm {norm:.3g} > {self.max_update_norm:.3g}"
        return None

    def fit_round(self) -> dict | None:
        """Run the offloaded fit (if due) under timeout/retry/validation.

        Returns the newly committed bank, or None (not due / round failed —
        in the failure case the offloader is rolled back to the last-good
        bank and, past ``quarantine_after`` consecutive failures, the user
        is quarantined).
        """
        if self.quarantined or not self.offloader.ready:
            return None
        t0 = time.perf_counter()
        with self._span("channel.fit_round", user=self.user, seq=self._seq,
                        version=self.version):
            out = self._fit_round(t0)
        if self.tm is not None:
            self.tm.registry.histogram("channel.fit_round_s").observe(
                time.perf_counter() - t0)
        return out

    def _fit_round(self, t0: float) -> dict | None:
        h = self.health_counters
        snap = self._snapshot()
        failure = "unknown"
        for attempt in range(1, self.policy.max_attempts + 1):
            h["fit_attempts"] += 1
            try:
                new = call_with_timeout(self.offloader.maybe_fit,
                                        self.policy.timeout_s)
            except FitTimeout:
                h["fit_timeouts"] += 1
                failure = "fit timeout"
                self._note_error("fit_timeout", failure, self._seq)
                self._restore(snap)
                h["backoff_s"] += self.policy.wait(attempt, self._rng)
                continue
            except Exception as e:  # numerical failure on the fit device
                h["fit_errors"] += 1
                failure = f"fit error: {e}"
                self._note_error("fit_error", failure, self._seq)
                self._restore(snap)
                h["backoff_s"] += self.policy.wait(attempt, self._rng)
                continue
            if new is None:       # raced interval gating; nothing due
                return None
            delivered = None
            for d in self._transmit("adapters", new):
                if d.late_ticks > self.policy.timeout_ticks:
                    h["late_dropped"] += 1
                    continue
                if d.late_ticks:
                    h["late_deliveries"] += 1
                delivered = d.obj if delivered is None else delivered
            if delivered is None:
                failure = "adapter return dropped"
                h["send_retries"] += 1
                self._note_error("fit_nack", failure, self._seq)
                self._restore(snap)    # refit is deterministic; retry whole round
                h["backoff_s"] += self.policy.wait(attempt, self._rng)
                continue
            reason = self._validate_bank(delivered)
            if reason is not None:
                h["fit_rejected"] += 1
                failure = reason
                self._note_error("fit_rejected", failure, self._seq)
                self._restore(snap)
                h["backoff_s"] += self.policy.wait(attempt, self._rng)
                continue
            # commit: bump version, snapshot last-good
            self.offloader.adapters = delivered
            self.version += 1
            self.last_good = delivered
            self._fail_streak = 0
            h["fits_committed"] += 1
            self._record("commit", version=self.version, attempts=attempt,
                         fit_s=time.perf_counter() - t0)
            if self.on_commit is not None:
                self.on_commit(self.user, self.version, delivered)
            return delivered
        # round failed: roll back to last-good, drop the round's data
        self._restore(snap)
        self.offloader.buffers.clear()
        self.dead_letters.append(DeadLetter(
            self.user, self._seq, "fit", failure, self.policy.max_attempts))
        h["dead_letters"] += 1
        h["rollbacks"] += 1
        self._fail_streak += 1
        self._note_error("rollback", failure, self._seq)
        if self.tm is not None:
            if self._fail_streak >= self.quarantine_after:
                # quarantine is terminal for the user: freeze the evidence
                self._record("quarantine", reason=failure,
                             fail_streak=self._fail_streak)
                self.tm.dump("user", self.user,
                             f"quarantined after {self._fail_streak} failed "
                             f"fit rounds: {failure}")
            else:
                self.tm.dump("user", self.user, f"fit rollback: {failure}")
        if self._fail_streak >= self.quarantine_after:
            self.quarantined = True
        return None

    # -- recovery (watchdog hook) -------------------------------------------
    def reset(self) -> None:
        """Channel reset after external recovery (straggler/hang checkpoint):
        drop in-flight buffers, restore the last-good bank, lift quarantine.
        Re-asserting the last-good bank also fences off any zombie fit — a
        timed-out ``maybe_fit`` keeps running on its abandoned worker thread
        and may have mutated the offloader after the rollback."""
        self.offloader.buffers.clear()
        self.offloader.adapters = self.last_good
        self.quarantined = False
        self._fail_streak = 0
        self._record("reset", version=self.version)
