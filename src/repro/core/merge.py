"""Parameter merging (paper §3.2 "Parameter merging", Prop 2, Alg. 1 l.3/8).

Merging folds every mergeable adapter's delta-W into the matching base weight;
unmerging subtracts it. Deltas are computed in f32 so that merge->unmerge
round-trips exactly in f32 parameters and to ~1 ulp in bf16.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import adapters as adapters_lib
from repro.core.taps import ColaSpec

# tap-name suffix -> path inside a block's param dict (final key is "w")
_SITE_PATHS = {
    "attn.q": ("attn", "q"),
    "attn.k": ("attn", "k"),
    "attn.v": ("attn", "v"),
    "attn.o": ("attn", "o"),
    "mlp.gate": ("mlp", "gate"),
    "mlp.up": ("mlp", "up"),
    "mlp.down": ("mlp", "down"),
    "ssm.in": ("ssm", "in_proj"),
    "ssm.out": ("ssm", "out_proj"),
}


def _tap_path(tap: str) -> tuple[str, ...]:
    prefix, suffix = tap.split(".", 1)
    return (prefix,) + _SITE_PATHS[suffix] + ("w",)


def _update_at(params: dict, path: tuple[str, ...], fn) -> dict:
    """Functional deep-update of a nested dict."""
    if len(path) == 1:
        new = dict(params)
        new[path[0]] = fn(params[path[0]])
        return new
    new = dict(params)
    new[path[0]] = _update_at(params[path[0]], path[1:], fn)
    return new


def merge_adapters(cfg: ModelConfig, params: dict, families: dict[str, str],
                   adapters: dict, scale: float, sign: float = 1.0) -> dict:
    """Return params with sign * scale * delta_W(adapter) added at every tap."""
    for tap, w in adapters.items():
        fam = families[tap]
        if not adapters_lib.is_mergeable(fam):
            raise ValueError(
                f"adapter family {fam!r} at {tap} is not mergeable (Prop 2)")
        delta = adapters_lib.merge_delta(fam, jax.tree.map(
            lambda a: a.astype(jnp.float32), w), scale)

        def add(base, delta=delta):
            return (base.astype(jnp.float32) + sign * delta).astype(base.dtype)

        params = _update_at(params, _tap_path(tap), add)
    return params


def unmerge_adapters(cfg: ModelConfig, params: dict, families: dict[str, str],
                     adapters: dict, scale: float) -> dict:
    return merge_adapters(cfg, params, families, adapters, scale, sign=-1.0)


def merge_adapter_pytrees(banks: list[dict], weights: list[float] | None = None
                          ) -> dict:
    """Weighted average of per-user adapter pytrees ("adapter soup") — the
    cluster-merge primitive for task-similarity clustering: one merged adapter
    serves every member of a cluster.

    For the ``linear`` family this is exactly the mean of the members'
    delta-Ws (Prop 2 merging commutes with averaging); for ``lowrank`` the
    leaf-wise mean is the standard rank-preserving approximation (the exact
    delta mean of K rank-r adapters is rank K*r). All banks must share one
    pytree structure and leaf shapes.
    """
    if not banks:
        raise ValueError("merge_adapter_pytrees: need at least one bank")
    if weights is None:
        weights = [1.0 / len(banks)] * len(banks)
    if len(weights) != len(banks):
        raise ValueError(f"got {len(banks)} banks but {len(weights)} weights")
    treedefs = {jax.tree.structure(b) for b in banks}
    if len(treedefs) != 1:
        raise ValueError(f"bank structures differ: {treedefs}")
    shapes = {tuple(l.shape for l in jax.tree.leaves(b)) for b in banks}
    if len(shapes) != 1:
        raise ValueError(f"bank leaf shapes differ: {shapes}")
    out = jax.tree.map(lambda l: weights[0] * l.astype(jnp.float32), banks[0])
    for w, b in zip(weights[1:], banks[1:]):
        out = jax.tree.map(lambda acc, l, w=w: acc + w * l.astype(jnp.float32),
                           out, b)
    return out


def merged_params(cfg: ModelConfig, params: dict, spec_or_families,
                  adapters: dict, scale: float | None = None) -> dict:
    if isinstance(spec_or_families, ColaSpec):
        families = spec_or_families.family_map
        scale = spec_or_families.scale if scale is None else scale
    else:
        families = spec_or_families
        assert scale is not None
    return merge_adapters(cfg, params, families, adapters, scale)
