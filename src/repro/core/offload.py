"""Gradient Offloading (paper Fig. 1): host-side buffers, the adaptation
interval I, int8 transfer compression, and the offloaded fit+optimizer.

The Offloader owns everything the paper moves off the server device:
- the adaptation-data buffers (accumulate I batches -> effective batch B*I),
- the adapter parameters between rounds,
- the adapter optimizer and its state (as in ZeRO-Offload, cited by the paper).

On a real pod the buffers live in host RAM of each worker (or a low-end
accelerator); here ``device`` defaults to the CPU device. Transfers are
asynchronous: ``push`` only enqueues; blocking happens inside ``maybe_fit``.
"""
from __future__ import annotations

import collections
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gl
from repro.core.taps import ColaSpec
from repro.optim import optimizers as optim_lib
from repro.telemetry import annotate

Array = jax.Array


# ---------------------------------------------------------------------------
# int8 row-scaled transfer compression (beyond-paper; §Perf)
# ---------------------------------------------------------------------------

def quant_int8(x: Array) -> tuple[Array, Array]:
    """Per-row (last-dim) symmetric int8 quantisation."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequant_int8(q: Array, scale: Array, dtype=jnp.float32) -> Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


class Offloader:
    """Buffers + offloaded fit for one adapter bank.

    Parameters
    ----------
    spec        : ColaSpec whose ``families`` describe the adapters to fit
                  (use the *adapter* spec even when the server runs merged).
    adapters    : initial adapter pytree {tap: w}.
    optimizer   : repro.optim Optimizer (state lives with the offloader).
    interval    : adaptation interval I (fit every I pushed batches).
    compress    : "none" | "int8" — compress (x, grad_h) for the transfer.
    """

    def __init__(self, spec: ColaSpec, adapters: dict, optimizer, *,
                 interval: int = 1, compress: str = "none", device=None):
        self.spec = spec
        self.optimizer = optimizer
        self.interval = int(interval)
        self.compress = compress
        self.device = device if device is not None else jax.devices("cpu")[0]
        self.adapters = jax.device_put(adapters, self.device)
        self.opt_state = jax.jit(optimizer.init)(self.adapters)
        self.buffers: dict[str, list] = collections.defaultdict(list)
        self._pushes = 0
        self.stats = {"pushed_bytes": 0, "fits": 0}

        def _fit(adapters, opt_state, data):
            grads = gl.fit_grads(self.spec, adapters, data)
            # average over the I buffered batches (effective batch B*I)
            grads = jax.tree.map(lambda g: g / float(self.interval), grads)
            updates, opt_state = optimizer.update(grads, opt_state, adapters)
            return optim_lib.apply_updates(adapters, updates), opt_state, grads

        self._fit = jax.jit(_fit)

    # -- transfer ----------------------------------------------------------
    def push(self, data: dict[str, tuple]) -> None:
        """Enqueue one batch of adaptation data {tap: (x, grad_h)}."""
        for tap, (x, gh) in data.items():
            if self.compress == "int8":
                payload = (quant_int8(x), quant_int8(gh))
                nbytes = sum(int(np.prod(p[0].shape)) + 4 * int(np.prod(p[1].shape))
                             for p in payload)
            else:
                payload = (x, gh)
                nbytes = x.size * x.dtype.itemsize + gh.size * gh.dtype.itemsize
            # device -> offload-device transfer (async under jax dispatch)
            payload = jax.device_put(payload, self.device)
            self.buffers[tap].append(payload)
            self.stats["pushed_bytes"] += nbytes
        self._pushes += 1

    def _materialise(self) -> dict[str, tuple]:
        out = {}
        for tap, items in self.buffers.items():
            xs, ghs = [], []
            for item in items:
                if self.compress == "int8":
                    (qx, sx), (qg, sg) = item
                    xs.append(dequant_int8(qx, sx))
                    ghs.append(dequant_int8(qg, sg))
                else:
                    xs.append(item[0])
                    ghs.append(item[1])
            axis = xs[0].ndim - 3  # batch axis: (L?, B, S, d)
            out[tap] = (jnp.concatenate(xs, axis=axis),
                        jnp.concatenate(ghs, axis=axis))
        return out

    @property
    def ready(self) -> bool:
        """True when I batches have accumulated and a fit is due."""
        return (self._pushes > 0 and self._pushes % self.interval == 0
                and bool(self.buffers))

    # -- fit ----------------------------------------------------------------
    def maybe_fit(self) -> dict | None:
        """Run the offloaded fit if I batches have accumulated. Returns the new
        adapters (to be sent back to the server / merged) or None."""
        if not self.ready:
            return None
        data = self._materialise()
        with annotate("offload.fit"):
            self.adapters, self.opt_state, _ = self._fit(
                self.adapters, self.opt_state, data)
        self.buffers.clear()
        self.stats["fits"] += 1
        return self.adapters

    def force_fit(self) -> dict | None:
        if not self.buffers:
            return None
        data = self._materialise()
        n = len(next(iter(self.buffers.values())))
        grads = gl.fit_grads(self.spec, self.adapters, data)
        grads = jax.tree.map(lambda g: g / float(n), grads)
        updates, self.opt_state = self.optimizer.update(
            grads, self.opt_state, self.adapters)
        self.adapters = optim_lib.apply_updates(self.adapters, updates)
        self.buffers.clear()
        self.stats["fits"] += 1
        return self.adapters
