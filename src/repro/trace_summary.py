"""Read back a Chrome-trace-event file exported by ``repro.telemetry``.

    PYTHONPATH=src python -m repro.trace_summary trace.json
    PYTHONPATH=src python -m repro.trace_summary trace.json --metrics snap.json

Validates the document against the trace-event schema (well-formed,
non-empty, spans properly nested per lane — the same check the tier-1 test
runs), then prints per-span-name latency stats (count, total, mean, p50/p95/
p99, max) and the slowest individual spans. With ``--metrics`` it also pretty-
prints a metrics snapshot JSON (``ServeEngine.telemetry_snapshot()`` /
``MetricRegistry.snapshot()`` output) next to the trace.

Open the same file in https://ui.perfetto.dev (or chrome://tracing) for the
interactive timeline.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.telemetry.metrics import percentiles
from repro.telemetry.tracing import validate_trace


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def span_table(doc: dict) -> list[dict]:
    """Aggregate complete events by name: count/total/mean/percentiles (ms)."""
    by_name: dict[str, list[float]] = {}
    for ev in doc.get("traceEvents", []):
        if isinstance(ev, dict) and ev.get("ph") == "X":
            by_name.setdefault(ev["name"], []).append(float(ev["dur"]) / 1e3)
    rows = []
    for name, durs in sorted(by_name.items()):
        p = percentiles(durs)
        rows.append({"name": name, "count": p["count"],
                     "total_ms": float(sum(durs)), "mean_ms": p["mean"],
                     "p50_ms": p["p50"], "p95_ms": p["p95"],
                     "p99_ms": p["p99"], "max_ms": p["max"]})
    rows.sort(key=lambda r: -r["total_ms"])
    return rows


def slowest(doc: dict, n: int = 5) -> list[dict]:
    evs = [ev for ev in doc.get("traceEvents", [])
           if isinstance(ev, dict) and ev.get("ph") == "X"]
    evs.sort(key=lambda ev: -float(ev["dur"]))
    return [{"name": ev["name"], "dur_ms": float(ev["dur"]) / 1e3,
             "ts_ms": float(ev["ts"]) / 1e3, "args": ev.get("args", {})}
            for ev in evs[:n]]


def summarize(doc: dict, report=print) -> int:
    problems = validate_trace(doc)
    if problems:
        for p in problems:
            report(f"INVALID: {p}")
        return 1
    rows = span_table(doc)
    n_events = sum(r["count"] for r in rows)
    report(f"valid trace-event JSON: {n_events} spans, "
           f"{len(rows)} distinct names")
    hdr = f"{'span':<20} {'count':>6} {'total_ms':>10} {'mean_ms':>9} " \
          f"{'p50_ms':>8} {'p95_ms':>8} {'p99_ms':>8} {'max_ms':>8}"
    report(hdr)
    for r in rows:
        report(f"{r['name']:<20} {r['count']:>6} {r['total_ms']:>10.2f} "
               f"{r['mean_ms']:>9.3f} {r['p50_ms']:>8.3f} {r['p95_ms']:>8.3f} "
               f"{r['p99_ms']:>8.3f} {r['max_ms']:>8.3f}")
    report("slowest spans:")
    for s in slowest(doc):
        args = f" {s['args']}" if s["args"] else ""
        report(f"  {s['name']:<20} {s['dur_ms']:.3f}ms @ {s['ts_ms']:.1f}ms"
               f"{args}")
    return 0


def summarize_metrics(path: str, report=print) -> None:
    with open(path) as f:
        snap = json.load(f)
    # a raw registry snapshot or a JSONL emit record ({"metrics": {...}})
    metrics = snap.get("metrics", snap) if isinstance(snap, dict) else snap
    report(f"metrics snapshot: {len(metrics)} series")
    for name, v in sorted(metrics.items()):
        if isinstance(v, dict):
            if v.get("count", 0) == 0:
                report(f"  {name}: (no samples)")
            else:
                report(f"  {name}: count={v['count']} mean={v['mean']:.6f} "
                       f"p50={v['p50']:.6f} p95={v['p95']:.6f} "
                       f"p99={v['p99']:.6f} max={v['max']:.6f}")
        else:
            report(f"  {name}: {v}")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.trace_summary", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    p.add_argument("trace", help="Chrome-trace-event JSON file")
    p.add_argument("--metrics", default=None,
                   help="metrics snapshot JSON to pretty-print alongside")
    args = p.parse_args(argv)
    rc = summarize(load(args.trace))
    if args.metrics:
        summarize_metrics(args.metrics)
    return rc


if __name__ == "__main__":
    sys.exit(main())
