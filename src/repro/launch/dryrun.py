"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell on
the production meshes and record memory/cost analysis + collective bytes.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single --out out.json
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi

The XLA_FLAGS lines below MUST run before any other import (jax locks the
device count on first init); nothing else in the package sets it.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.analysis import roofline
from repro.configs import registry
from repro.configs.base import ColaConfig
from repro.distributed import sharding as sh
from repro.distributed import steps
from repro.launch.mesh import make_production_mesh
from repro.models import model as model_lib


def _compile_cell(cfg, spec, mesh, cc):
    if spec.kind == "train":
        batch = registry.batch_specs(cfg, spec.batch, spec.seq)
        bs = sh.batch_shardings(mesh, batch, policy=cfg.shard_policy)
        params = steps.shaped_params(cfg)
        if cc.mode == "ft":
            fn, (ps, _), _ = steps.make_train_step(cfg, cc, mesh)
            jitted = jax.jit(fn, in_shardings=(ps, bs))
            lowered = jitted.lower(params, batch)
        else:
            fn, (ps, ash, _), _ = steps.make_train_step(cfg, cc, mesh)
            adapters = steps.shaped_adapters(cfg, cc)
            jitted = jax.jit(fn, in_shardings=(ps, ash, bs))
            lowered = jitted.lower(params, adapters, batch)
    elif spec.kind == "prefill":
        fn, ps = steps.make_prefill_step(cfg, mesh)
        batch = registry.batch_specs(cfg, spec.batch, spec.seq)
        bs = sh.batch_shardings(mesh, batch)
        params = steps.shaped_params(cfg)
        outs = steps.prefill_out_shardings(cfg, mesh, spec.batch, spec.seq)
        jitted = jax.jit(fn, in_shardings=(ps, bs), out_shardings=outs)
        lowered = jitted.lower(params, batch)
    else:  # decode
        fn, ps = steps.make_serve_step(cfg, mesh)
        cache = model_lib.cache_specs(cfg, spec.batch, spec.seq)
        cache_sh, tok_sh = steps.serve_shardings(cfg, mesh, spec.batch,
                                                 spec.seq)
        batch = registry.decode_token_specs(cfg, spec.batch)
        params = steps.shaped_params(cfg)
        # out_shardings must match the donated cache input for buffer aliasing
        out_tok = sh.batch_shardings(
            mesh, jax.eval_shape(
                lambda: jnp.zeros((spec.batch, 1)
                                  + ((cfg.n_codebooks,) if cfg.n_codebooks
                                     else ()), jnp.int32)))
        jitted = jax.jit(fn, in_shardings=(ps, cache_sh, tok_sh),
                         out_shardings=(out_tok, cache_sh),
                         donate_argnums=(1,))
        lowered = jitted.lower(params, cache, batch)
    return lowered.compile()


def _extrapolated_costs(cfg, spec, mesh, cc):
    """Exact HLO cost totals via two-point layer extrapolation.

    XLA's cost_analysis counts a while-loop body ONCE (calibrated), and fully
    unrolling the production configs is prohibitively slow to compile. Layer
    stacks are homogeneous, so costs are affine in the layer count: compile
    the cell at n1 and n2 layers with every *inner* scan unrolled (cheap at
    1-2 layers), and extrapolate  total = f(n1) + (units-1) * (f(n2)-f(n1)).
    Microbatching is disabled for the cost compile (same total FLOPs; the
    accumulation adds are negligible). loss_chunk likewise.
    """
    from repro import flags as repro_flags
    plan = model_lib.layer_plan(cfg)
    if plan[0] == "pairs":
        n1, n2, units = 2, 4, cfg.n_layers / 2
    elif plan[0] == "hybrid":
        e = cfg.shared_attn_every
        n1, n2, units = e, 2 * e, cfg.n_layers / e
    else:
        n1, n2, units = 1, 2, cfg.n_layers

    # The cost compile runs in f32: XLA CPU emulates bf16 dots via hoisted f32
    # converts, which would pollute byte/collective counts with traffic that
    # does not exist on TPU. f32 is native on CPU; bytes and collective bytes
    # are then halved to model bf16 TPU execution. FLOPs are dtype-independent.
    dt = cfg.compute_dtype
    scale_bytes = 0.5 if dt in ("bfloat16", "bf16", "float16") else 1.0
    keys = ("flops", "bytes accessed", "collective")

    def costs_at(n_layers: int, seq: int) -> dict:
        c = cfg.replace(n_layers=n_layers, microbatches=1, loss_chunk=0,
                        param_dtype="float32", compute_dtype="float32")
        s = dataclasses.replace(spec, seq=seq)
        with repro_flags.override(unroll_scans=True), mesh:
            comp = _compile_cell(c, s, mesh, cc)
        ca = comp.cost_analysis()
        return {
            "flops": ca.get("flops", 0.0),
            "bytes accessed": scale_bytes * ca.get("bytes accessed", 0.0),
            "collective": scale_bytes * roofline.collective_bytes(
                comp.as_text()),
        }

    def layer_extrapolated(seq: int) -> dict:
        f1, f2 = costs_at(n1, seq), costs_at(n2, seq)
        return {k: f1[k] + (units - 1.0) * (f2[k] - f1[k]) for k in keys}

    # Every cost is a polynomial of degree <=2 in the sequence length
    # (attention S^2; SSD chunks, conv, projections, dispatch: linear).
    # Unrolling inner scans at long S explodes compile time, so long-seq (and
    # SSD-heavy) cells are fit with a polynomial in S and evaluated at the
    # target — exact for polynomial scaling. Local-window attention changes
    # the polynomial at S=window, so the fit points sit above the window.
    # Pure-SSM archs are exactly linear in S; decode is linear in cache len.
    if cfg.family == "ssm":
        deg, pts = 1, [512, 1024]
    elif cfg.family == "hybrid":
        deg, pts = 2, [512, 768, 1024]
    elif spec.kind == "decode":
        deg, pts = 1, [2048, 4096]
    else:
        base = 2048
        if cfg.attn_pattern == "local_global":
            base = max(base, 2 * cfg.local_window)
        deg, pts = 2, [base, base + base // 2, 2 * base]
    if spec.seq <= max(pts) or (spec.seq <= 8192 and cfg.family not in
                                ("ssm", "hybrid")):
        out = layer_extrapolated(spec.seq)
        return out, out.pop("collective")
    vals = [layer_extrapolated(s) for s in pts]
    import numpy as _np
    out = {}
    for k in keys:
        coef = _np.polyfit(_np.array(pts, float),
                           _np.array([v[k] for v in vals], float), deg)
        out[k] = float(_np.polyval(coef, float(spec.seq)))
    return out, out.pop("collective")


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               cola_mode: str = "fused_fit", overrides: dict | None = None,
               verbose: bool = True, cost_pass: bool = True) -> dict:
    """Lower+compile one (arch, shape) cell; return the §Dry-run/§Roofline record.

    Two compiles per cell:
    - memory pass: scans rolled (realistic schedule) -> memory_analysis.
    - cost pass: scans unrolled -> exact HLO_FLOPs / bytes / collective totals
      (XLA cost_analysis counts loop bodies once; see repro.flags).
    """
    cfg = registry.get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    spec = registry.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    cc = ColaConfig(mode=cola_mode, family="lowrank", taps="qv", rank=16)

    t0 = time.time()
    with mesh:
        compiled = _compile_cell(cfg, spec, mesh, cc)
    t1 = time.time()
    mem = compiled.memory_analysis()
    # XLA *CPU* emulates bf16 dots by hoisting f32 converts of the bf16
    # operands (weight stacks, KV caches) out of the layer loop — persistent
    # f32 shadow copies that do not exist on TPU (native bf16 MXU). The shadow
    # is 2x the bf16 argument bytes; report a TPU-representative corrected
    # peak alongside the raw CPU number. (Verified against the buffer
    # assignment: e.g. decode_32k mistral-large carries two
    # f32[88,8,2048,8,128] copies of the bf16 KV cache.)
    emu = 2 * int(getattr(mem, "argument_size_in_bytes", 0) or 0)

    if cost_pass:
        cost, coll = _extrapolated_costs(cfg, spec, mesh, cc)
    else:
        cost = compiled.cost_analysis()
        coll = roofline.collective_bytes(compiled.as_text())
    t2 = time.time()

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "mode": cola_mode,
        "kind": spec.kind,
        "compile_s": round(t1 - t0, 1),
        "cost_compile_s": round(t2 - t1, 1),
        "memory": roofline.memory_record(mem),
        "cpu_bf16_emulation_bytes": emu,
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": coll,
        "devices": mesh.devices.size,
        "exact_costs": bool(cost_pass),
    }
    peak = rec["memory"].get("peak_bytes_per_device", 0)
    rec["memory"]["peak_corrected_tpu"] = max(0, peak - emu)
    rec.update(roofline.roofline_terms(rec))
    rec["model_flops"] = roofline.model_flops(cfg, spec)
    # cost_analysis flops are per-device; model_flops is global
    rec["useful_ratio"] = (rec["model_flops"] / (rec["flops"] * rec["devices"])
                           if rec["flops"] else 0.0)
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} ({rec['mesh']}, {cola_mode}) "
              f"compiled in {rec['compile_s']}s")
        print("  memory_analysis:", json.dumps(rec["memory"]))
        print(f"  flops={rec['flops']:.3e} bytes={rec['bytes_accessed']:.3e} "
              f"collective={rec['collective_bytes']:.3e}")
        print(f"  terms(s): compute={rec['t_compute']:.4e} "
              f"memory={rec['t_memory']:.4e} collective={rec['t_collective']:.4e}"
              f" -> bottleneck={rec['bottleneck']}")
    return rec


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    p.add_argument("--mode", default="fused_fit",
                   choices=["fused_fit", "faithful_offload", "ft", "frozen"])
    p.add_argument("--all", action="store_true")
    p.add_argument("--out", default=None, help="append JSON records to file")
    p.add_argument("--no-cost-pass", action="store_true",
                   help="skip the unrolled cost compile (fast; approx costs)")
    p.add_argument("--override", default=None,
                   help="comma k=v model-config overrides (ints/floats/strs)")
    p.add_argument("--skip-done", action="store_true",
                   help="skip cells already present in --out")
    args = p.parse_args(argv)

    overrides = {}
    if args.override:
        for kv in args.override.split(","):
            k, v = kv.split("=")
            try:
                overrides[k] = int(v)
            except ValueError:
                try:
                    overrides[k] = float(v)
                except ValueError:
                    overrides[k] = v

    cells: list[tuple[str, str]]
    if args.all:
        cells = registry.all_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    done = set()
    if args.skip_done and args.out and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass
    records, failures = [], []
    for arch, shape in cells:
        for mp in meshes:
            if (arch, shape, "pod2x16x16" if mp else "pod16x16") in done:
                continue
            try:
                rec = lower_cell(arch, shape, multi_pod=mp, cola_mode=args.mode,
                                 overrides=overrides or None,
                                 cost_pass=not args.no_cost_pass)
                records.append(rec)
                if args.out:   # flush per cell (crash-safe)
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
            except Exception as e:  # noqa: BLE001 — report every cell
                traceback.print_exc()
                failures.append({"arch": arch, "shape": shape,
                                 "multi_pod": mp, "error": repr(e)})
    if args.out and failures:
        with open(args.out + ".failures", "a") as f:
            for r in failures:
                f.write(json.dumps(r) + "\n")
    print(f"\n[dryrun] {len(records)} cells OK, {len(failures)} failed")
    for f_ in failures:
        print("  FAILED:", f_)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
