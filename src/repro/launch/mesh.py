"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state. The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
real launches get the mesh from the actual TPU topology.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(data: int, model: int, pods: int = 1):
    """Arbitrary mesh for tests / small runs (e.g. (2, 4) on 8 host devices)."""
    if pods > 1:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def single_device_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))
