"""Perf-loop probe: compile one cell with config overrides and print the
roofline terms + collective breakdown. The workhorse of §Perf iterations.

  PYTHONPATH=src python -m repro.launch.perf_probe --arch smollm-135m \
      --shape train_4k --override shard_policy=dp --tag dp_only
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import dataclasses
import json
import sys

import jax

from repro.analysis import collectives, roofline
from repro.configs import registry
from repro.configs.base import ColaConfig
from repro.launch import dryrun
from repro.launch.mesh import make_production_mesh


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", required=True)
    p.add_argument("--mode", default="fused_fit")
    p.add_argument("--override", default=None)
    p.add_argument("--tag", default="probe")
    p.add_argument("--breakdown", action="store_true",
                   help="print collective breakdown of the (rolled) program")
    p.add_argument("--no-cost-pass", action="store_true")
    p.add_argument("--out", default="dryrun_perf.jsonl")
    args = p.parse_args(argv)

    overrides = {}
    if args.override:
        for kv in args.override.split(","):
            k, v = kv.split("=")
            try:
                overrides[k] = int(v)
            except ValueError:
                try:
                    overrides[k] = float(v)
                except ValueError:
                    overrides[k] = v

    rec = dryrun.lower_cell(args.arch, args.shape, cola_mode=args.mode,
                            overrides=overrides or None,
                            cost_pass=not args.no_cost_pass)
    rec["tag"] = args.tag
    rec["overrides"] = overrides
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")

    if args.breakdown:
        cfg = registry.get_config(args.arch)
        if overrides:
            cfg = cfg.replace(**overrides)
        spec = registry.SHAPES[args.shape]
        mesh = make_production_mesh()
        cc = ColaConfig(mode=args.mode, family="lowrank", taps="qv", rank=16)
        with mesh:
            comp = dryrun._compile_cell(cfg, spec, mesh, cc)
        print("[collective breakdown — rolled program; loop bodies appear "
              "once but execute per layer]")
        collectives.print_breakdown(comp.as_text(), report=print)
    return 0


if __name__ == "__main__":
    sys.exit(main())
