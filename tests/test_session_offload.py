"""System behaviour: ColaSession training modes agree; Offloader interval
semantics; merged training; collaboration; compression path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ColaConfig
from repro.core import gl
from repro.core.collab import CollabSession, mask_user_rows
from repro.core.session import ColaSession
from repro.data.pipeline import SyntheticLM
from repro.models import model as M
from repro.optim import optimizers as opt


def _mk(arch="smollm-135m", **cc_kw):
    cfg = registry.reduced_config(arch).replace(n_layers=2, d_model=64,
                                                n_heads=4, n_kv_heads=2,
                                                d_head=16, d_ff=128,
                                                vocab_size=128)
    key = jax.random.PRNGKey(0)
    params = M.init(cfg, key)
    data = SyntheticLM(cfg, batch=4, seq=16, seed=1)
    return cfg, params, data, key


def _run(cfg, params, data, key, steps=6, **cc_kw):
    cc = ColaConfig(**cc_kw)
    sess = ColaSession(cfg, cc, params, key, optimizer=opt.sgd(0.1))
    losses = [sess.step(data.batch_at(t)) for t in range(steps)]
    return sess, losses


def test_all_modes_equivalent_trajectories():
    """ColA(LowRank) Mode A == Mode B == LoRA, step by step (Prop 1 applied
    over a whole training run with the same SGD optimizer)."""
    cfg, params, data, key = _mk()
    _, l_a = _run(cfg, params, data, key, mode="faithful_offload",
                  family="lowrank", taps="qv", rank=4)
    _, l_b = _run(cfg, params, data, key, mode="fused_fit",
                  family="lowrank", taps="qv", rank=4)
    _, l_l = _run(cfg, params, data, key, mode="lora",
                  family="lowrank", taps="qv", rank=4)
    np.testing.assert_allclose(l_a, l_b, rtol=1e-4)
    np.testing.assert_allclose(l_a, l_l, rtol=1e-4)


def test_merged_training_matches_unmerged():
    cfg, params, data, key = _mk()
    s1, l_unmerged = _run(cfg, params, data, key, mode="faithful_offload",
                          family="lowrank", taps="qv", rank=4, merged=False)
    s2, l_merged = _run(cfg, params, data, key, mode="faithful_offload",
                        family="lowrank", taps="qv", rank=4, merged=True)
    np.testing.assert_allclose(l_unmerged, l_merged, rtol=1e-3, atol=1e-4)
    for tap in s1.adapters:
        for leaf in s1.adapters[tap]:
            np.testing.assert_allclose(np.asarray(s1.adapters[tap][leaf]),
                                       np.asarray(s2.adapters[tap][leaf]),
                                       rtol=1e-3, atol=1e-5)


def _is_qv(path) -> bool:
    keys = [str(getattr(p, "key", p)) for p in path]
    return "attn" in keys and any(k in ("q", "v") for k in keys)


def test_linear_merged_matches_full_ft():
    """Paper §C.3: ColA(Linear, merged) == full fine-tuning of exactly the
    tapped weights. Ground truth: a masked full-FT run (SGD applied to the
    attn q/v weights only, everything else frozen) on the same batches —
    the loss trajectories and trained weight deltas must agree, and every
    untapped weight must stay bit-identical. (The previous assertion
    ``loss[-1] < loss[0]`` measured cross-batch noise, not correctness:
    q/v-only training moves this tiny model's loss by less than the
    batch-to-batch variance, so it failed spuriously.)"""
    cfg, params, data, key = _mk()
    sess, l_cola = _run(cfg, params, data, key, mode="faithful_offload",
                        family="linear", taps="qv", merged=True)
    _, l_b = _run(cfg, params, data, key, mode="fused_fit", family="linear",
                  taps="qv")
    np.testing.assert_allclose(l_cola, l_b, rtol=1e-3, atol=1e-4)

    # masked full-FT ground truth
    from repro.core import gl
    step_ft = jax.jit(lambda p, b: gl.train_step_ft(cfg, p, b)[:2])
    p_ft, l_ft = params, []
    for t in range(len(l_cola)):
        loss, grads = step_ft(p_ft, data.batch_at(t))
        l_ft.append(float(loss))
        p_ft = jax.tree_util.tree_map_with_path(
            lambda path, p, g: (p - 0.1 * g) if _is_qv(path) else p,
            p_ft, grads)
    np.testing.assert_allclose(l_cola, l_ft, rtol=0, atol=1e-5)

    # merged inference weights == the FT-trained weights, and the deltas
    # live only on the tapped q/v projections
    merged = sess.inference_params()
    for (path, m), (_, f), (_, p0) in zip(
            jax.tree_util.tree_flatten_with_path(merged)[0],
            jax.tree_util.tree_flatten_with_path(p_ft)[0],
            jax.tree_util.tree_flatten_with_path(params)[0]):
        if _is_qv(path):
            np.testing.assert_allclose(np.asarray(m), np.asarray(f),
                                       rtol=0, atol=1e-6)
        else:
            np.testing.assert_array_equal(np.asarray(m), np.asarray(p0))


def test_interval_accumulation():
    """Interval I: adapters update every I steps with the averaged gradient —
    equivalent to one big batch."""
    cfg, params, data, key = _mk()
    sess, _ = _run(cfg, params, data, key, steps=4, mode="faithful_offload",
                   family="lowrank", taps="qv", rank=4, interval=4)
    # after 4 pushes exactly one fit happened
    assert sess.offloader.stats["fits"] == 1
    # equivalent single-step on the concatenated batch
    big = {k: np.concatenate([data.batch_at(t)[k] for t in range(4)])
           for k in data.batch_at(0)}
    sess2 = ColaSession(cfg, ColaConfig(mode="faithful_offload",
                                        family="lowrank", taps="qv", rank=4),
                        params, key, optimizer=opt.sgd(0.1))
    sess2.step({k: jnp.asarray(v) for k, v in big.items()})
    for tap in sess.adapters:
        for leaf in sess.adapters[tap]:
            np.testing.assert_allclose(np.asarray(sess.adapters[tap][leaf]),
                                       np.asarray(sess2.adapters[tap][leaf]),
                                       rtol=1e-3, atol=1e-6)


def test_compression_int8_close_to_exact():
    cfg, params, data, key = _mk()
    s1, _ = _run(cfg, params, data, key, mode="faithful_offload",
                 family="lowrank", taps="qv", rank=4)
    s2, _ = _run(cfg, params, data, key, mode="faithful_offload",
                 family="lowrank", taps="qv", rank=4, compress="int8")
    a1 = np.concatenate([np.asarray(x).ravel()
                         for x in jax.tree.leaves(s1.adapters)])
    a2 = np.concatenate([np.asarray(x).ravel()
                         for x in jax.tree.leaves(s2.adapters)])
    # int8 transfer perturbs the updates but must stay close
    assert np.corrcoef(a1, a2)[0, 1] > 0.99


def test_inference_params_merge():
    cfg, params, data, key = _mk()
    sess, _ = _run(cfg, params, data, key, mode="lora", family="lowrank",
                   taps="qv", rank=4)
    merged = sess.inference_params()
    batch = data.batch_at(0)
    lm, _ = M.loss_fn(cfg, merged, batch)
    la = sess.eval_loss(batch)
    np.testing.assert_allclose(float(lm), la, rtol=1e-4)


@pytest.mark.parametrize("family", ["lowrank", "linear"])
def test_user_row_masking_exact(family):
    """Per-user gradient isolation: masked fits decompose the merged gradient
    exactly, for both the fused lowrank kernel path and the generic VJP path
    (linear) — the two families CollabSession mixes in FTaaS."""
    cfg, params, data, key = _mk()
    cc = ColaConfig(mode="faithful_offload", family=family, taps="qv", rank=4)
    spec = gl.make_spec(cfg, cc)
    adapters = gl.init_adapters(cfg, cc, key)
    batch = data.batch_at(0)
    users = jnp.array([0, 1, 0, 1])
    _, d_all, _ = gl.server_step_a(cfg, spec, params, adapters, batch)
    g_user0 = gl.fit_grads(spec, adapters, mask_user_rows(d_all, users, 0))
    g_user1 = gl.fit_grads(spec, adapters, mask_user_rows(d_all, users, 1))
    g_sum = gl.fit_grads(spec, adapters, d_all)
    for tap in g_sum:
        for leaf in g_sum[tap]:
            np.testing.assert_allclose(
                np.asarray(g_user0[tap][leaf]) + np.asarray(g_user1[tap][leaf]),
                np.asarray(g_sum[tap][leaf]), rtol=1e-4, atol=1e-6)


def test_collab_gradient_isolation_mixed_families():
    """Regression (extends test_user_row_masking_exact to the full session):
    merged training with mixed adapter families (lowrank + linear) keeps
    per-user gradients isolated — a user whose rows never appear gets a
    bit-identical adapter bank, while the active user's bank trains."""
    cfg, params, data, key = _mk()
    cc = ColaConfig(mode="faithful_offload", family="lowrank", taps="qv",
                    rank=4, merged=True, users=2)
    collab = CollabSession(cfg, cc, params, key, optimizer=opt.sgd(0.1),
                           families=["lowrank", "linear"])
    init_u0 = jax.tree.map(np.asarray, collab.offloaders[0].adapters)
    init_u1 = jax.tree.map(np.asarray, collab.offloaders[1].adapters)
    data_u = SyntheticLM(cfg, batch=4, seq=16, seed=2, users=2)
    for t in range(3):
        b = {k: jnp.asarray(v) for k, v in data_u.batch_at(t).items()
             if k != "user_id"}
        # every row belongs to user 0; user 1 must receive exact-zero updates
        collab.train_step(b, jnp.zeros((4,), jnp.int32))
    for a, b in zip(jax.tree.leaves(init_u1),
                    jax.tree.leaves(collab.offloaders[1].adapters)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    changed = [not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(init_u0),
                               jax.tree.leaves(collab.offloaders[0].adapters))]
    assert any(changed), "active user's adapters did not train"


def test_collab_session_runs_and_merges():
    cfg, params, data, key = _mk()
    cc = ColaConfig(mode="faithful_offload", family="lowrank", taps="qv",
                    rank=4, merged=True, users=2)
    collab = CollabSession(cfg, cc, params, key, optimizer=opt.sgd(0.1),
                           families=["lowrank", "linear"])
    data_u = SyntheticLM(cfg, batch=4, seq=16, seed=2, users=2)
    losses = []
    for t in range(4):
        b = data_u.batch_at(t)
        users = jnp.asarray(b.pop("user_id"))
        losses.append(collab.train_step(
            {k: jnp.asarray(v) for k, v in b.items()}, users))
    assert all(np.isfinite(losses))
    merged = collab.merged_model()
    loss, _ = M.loss_fn(cfg, merged, {k: jnp.asarray(v) for k, v in
                                      data_u.batch_at(9).items()
                                      if k != "user_id"})
    assert np.isfinite(float(loss))
