"""Runtime substrate: checkpoint/restore (atomic, async, elastic), train-loop
restart-resume, watchdog straggler detection, serving engine (continuous
batching + multi-LoRA)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ColaConfig
from repro.core import gl
from repro.core.session import ColaSession
from repro.data.pipeline import ByteCorpus, SyntheticLM
from repro.models import model as M
from repro.optim import optimizers as opt
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.serve_loop import Request, ServeEngine, stack_user_adapters
from repro.runtime.train_loop import TrainLoop
from repro.runtime.watchdog import Watchdog


def _tiny():
    cfg = registry.reduced_config("smollm-135m").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=128)
    key = jax.random.PRNGKey(0)
    return cfg, M.init(cfg, key), key


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_retention(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": {"w": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3)},
            "step": jnp.asarray(7)}
    for s in (1, 2, 3):
        cm.save(s, tree)
    assert cm.steps() == [2, 3]
    step, back = cm.restore()
    assert step == 3
    np.testing.assert_array_equal(np.asarray(back["a"]["w"], np.float32),
                                  np.asarray(tree["a"]["w"], np.float32))
    assert back["a"]["w"].dtype == np.dtype("bfloat16")


def test_checkpoint_async_and_atomic(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=3)
    tree = {"w": jnp.ones((128, 128))}
    cm.save_async(10, tree)
    cm.wait()
    assert cm.latest_step() == 10
    assert not [f for f in os.listdir(tmp_path) if ".tmp" in f]


def test_train_loop_restart_resumes(tmp_path):
    cfg, params, key = _tiny()
    data = SyntheticLM(cfg, batch=4, seq=16, seed=3)

    def fresh_session():
        return ColaSession(cfg, ColaConfig(mode="lora", family="lowrank",
                                           taps="qv", rank=4),
                           params, key, optimizer=opt.sgd(0.05))

    # uninterrupted run to 8 steps
    full = TrainLoop(fresh_session(), data, str(tmp_path / "a"), ckpt_every=2)
    full.run(8, resume=False)
    ref_adapters = full.session.adapters

    # interrupted run: 4 steps, then a new process resumes to 8
    loop1 = TrainLoop(fresh_session(), data, str(tmp_path / "b"), ckpt_every=2)
    loop1.run(4, resume=False)
    loop2 = TrainLoop(fresh_session(), data, str(tmp_path / "b"), ckpt_every=2)
    out = loop2.run(8, resume=True)
    assert loop2.session.step_count == 8
    for a, b in zip(jax.tree.leaves(ref_adapters),
                    jax.tree.leaves(loop2.session.adapters)):
        # trajectories agree to optimizer-noise level (XLA CPU reductions are
        # not bitwise deterministic across separate jit instances)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


def test_elastic_restore_new_topology(tmp_path):
    """Checkpoints are topology-free: arrays restore under any sharding."""
    cm = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    cm.save(1, tree)
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    _, back = cm.restore(shardings=sh)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(tree["w"]))


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------

def test_watchdog_flags_stragglers():
    events = []
    wd = Watchdog(threshold=3.0, on_straggler=lambda *a: events.append(a))
    import time
    for step in range(12):
        wd.start_step()
        time.sleep(0.001)
        wd.end_step(step)
    wd.start_step()
    time.sleep(0.05)
    wd.end_step(99)
    assert wd.stragglers and wd.stragglers[-1][0] == 99
    assert events


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_restartable():
    cfg, _, _ = _tiny()
    d1 = SyntheticLM(cfg, batch=4, seq=16, seed=5)
    d2 = SyntheticLM(cfg, batch=4, seq=16, seed=5)
    b1, b2 = d1.batch_at(17), d2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d1.batch_at(18)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_byte_corpus(tmp_path):
    p = tmp_path / "corpus.txt"
    p.write_bytes(b"hello world, this is a tiny corpus for byte-level lm " * 20)
    d = ByteCorpus(str(p), batch=2, seq=32, seed=0)
    b = d.batch_at(0)
    assert b["tokens"].shape == (2, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def test_serve_engine_continuous_batching():
    cfg, params, key = _tiny()
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    for rid in range(4):
        eng.submit(Request(rid=rid, user=0,
                           prompt=np.arange(3 + rid) % cfg.vocab_size,
                           max_new=4))
    eng.run_until_idle()
    assert eng.stats["completed"] == 4
    assert eng.stats["tokens"] >= 16


def test_serve_engine_multi_user_adapters_route_correctly():
    """Two users with very different adapters must get different outputs, and
    each must match the single-user merged model."""
    cfg, params, key = _tiny()
    cc = ColaConfig(mode="lora", family="lowrank", taps="qv", rank=4)
    ad0 = gl.init_adapters(cfg, cc, jax.random.fold_in(key, 1))
    ad1 = gl.init_adapters(cfg, cc, jax.random.fold_in(key, 2))
    ad1 = jax.tree.map(lambda a: a + 0.5 * jax.random.normal(
        jax.random.fold_in(key, 3), a.shape), ad1)

    prompt = np.arange(8) % cfg.vocab_size
    outs = {}
    for user, _ in enumerate((ad0, ad1)):
        eng = ServeEngine(cfg, params, slots=2, max_len=64,
                          user_adapters=[ad0, ad1])
        eng.submit(Request(rid=0, user=user, prompt=prompt, max_new=6))
        eng.run_until_idle()
        outs[user] = eng.stats and eng  # keep engine
    # compare against per-user dedicated engines using merged weights
    from repro.core import merge as merge_lib
    spec = gl.make_spec(cfg, cc)
    for user, ad in enumerate((ad0, ad1)):
        merged = merge_lib.merged_params(cfg, params, dict(spec.families), ad,
                                         1.0)
        ref_eng = ServeEngine(cfg, merged, slots=2, max_len=64)
        r = Request(rid=0, user=0, prompt=prompt, max_new=6)
        ref_eng.submit(r)
        ref_eng.run_until_idle()
        ml_eng = ServeEngine(cfg, params, slots=2, max_len=64,
                             user_adapters=[ad0, ad1])
        r2 = Request(rid=0, user=user, prompt=prompt, max_new=6)
        ml_eng.submit(r2)
        ml_eng.run_until_idle()
        assert r2.out == r.out, f"user {user}: multi-lora != merged"


def test_watchdog_end_step_without_start_raises():
    from repro.runtime.watchdog import WatchdogError
    wd = Watchdog()
    with pytest.raises(WatchdogError, match="without a matching start_step"):
        wd.end_step(0)
    # and the error is not an AssertionError (must survive python -O)
    assert not issubclass(WatchdogError, AssertionError)


def test_watchdog_heartbeat_survives_disk_errors(tmp_path):
    """A missed heartbeat (full/read-only/vanished disk) is an observability
    gap, not a training failure: end_step must still return and count the
    failure in stats."""
    good = Watchdog(heartbeat_path=str(tmp_path / "hb.json"))
    good.start_step()
    good.end_step(0)
    assert good.stats == {"steps": 1, "heartbeats": 1,
                          "heartbeat_failures": 0}

    bad = Watchdog(heartbeat_path=str(tmp_path / "no_such_dir" / "hb.json"))
    for step in range(3):
        bad.start_step()
        dt = bad.end_step(step)
        assert dt >= 0.0
    assert bad.stats == {"steps": 3, "heartbeats": 0,
                         "heartbeat_failures": 3}
