"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import cola_fit as ck
from repro.kernels import flash_attention as fa
from repro.kernels import multi_lora as ml
from repro.kernels import ops, ref, ssd_scan


def _tol(dt):
    return dict(rtol=2e-2, atol=5e-2) if dt == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("S,H,K,D", [(128, 4, 4, 64), (256, 4, 2, 64),
                                     (256, 8, 2, 128), (128, 6, 3, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_fwd_sweep(S, H, K, D, dtype):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, S, H, D), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, S, K, D), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, S, K, D), dtype)
    pos = jnp.arange(S)[None]
    o_ref = ref.sdpa(q, k, v, q_positions=pos, kv_positions=pos)
    o = fa.flash_attention(q, k, v, interpret=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window,softcap", [(None, None), (64, None),
                                            (None, 30.0), (64, 30.0)])
def test_flash_attention_masking_variants(window, softcap):
    key = jax.random.PRNGKey(1)
    S, H, K, D = 256, 4, 2, 64
    q = jax.random.normal(key, (1, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, S, K, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, S, K, D))
    pos = jnp.arange(S)[None]
    o_ref = ref.sdpa(q, k, v, q_positions=pos, kv_positions=pos,
                     window=window, softcap=softcap)
    o = fa.flash_attention(q, k, v, window=window, softcap=softcap,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-5)


def test_flash_attention_backward():
    key = jax.random.PRNGKey(2)
    S, H, K, D = 128, 4, 2, 64
    q = jax.random.normal(key, (1, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, S, K, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, S, K, D))
    pos = jnp.arange(S)[None]

    def loss_ref(q, k, v):
        return jnp.sum(ref.sdpa(q, k, v, q_positions=pos,
                                kv_positions=pos) ** 2)

    def loss_ker(q, k, v):
        return jnp.sum(fa.flash_attention(q, k, v, interpret=True) ** 2)

    g1 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ker, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=2e-4)


@pytest.mark.parametrize("T,din,dout,r", [(256, 128, 128, 8), (512, 192, 96, 16),
                                          (128, 64, 256, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cola_fit_sweep(T, din, dout, r, dtype):
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (T, din), dtype)
    g = jax.random.normal(jax.random.fold_in(key, 1), (T, dout), dtype) * 0.01
    A = jax.random.normal(jax.random.fold_in(key, 2), (din, r), jnp.float32)
    B = jax.random.normal(jax.random.fold_in(key, 3), (r, dout), jnp.float32)
    dA1, dB1 = ref.cola_fit_lowrank(x, g, A, B, scale=1.0)
    dA2, dB2 = ck.cola_fit_lowrank(x, g, A, B, scale=1.0, interpret=True)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dA1), np.asarray(dA2), **tol)
    np.testing.assert_allclose(np.asarray(dB1), np.asarray(dB2), **tol)


@pytest.mark.parametrize("T,U,din,dout,r", [(128, 2, 64, 64, 4),
                                            (256, 8, 128, 96, 8),
                                            (64, 3, 192, 128, 16)])
def test_multi_lora_sweep(T, U, din, dout, r):
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (T, din))
    A = jax.random.normal(jax.random.fold_in(key, 1), (U, din, r))
    B = jax.random.normal(jax.random.fold_in(key, 2), (U, r, dout))
    idx = jax.random.randint(jax.random.fold_in(key, 3), (T,), 0, U)
    y1 = ref.multi_lora(x, A, B, idx, scale=0.5)
    y2 = ml.multi_lora(x, A, B, idx, scale=0.5, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("S,chunk", [(256, 64), (96, 32), (512, 128)])
def test_ssd_chunked_matches_quadratic(S, chunk):
    key = jax.random.PRNGKey(5)
    b, H, P, N = 2, 4, 16, 8
    x = jax.random.normal(key, (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (b, S, H)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.1)
    B = jax.random.normal(jax.random.fold_in(key, 3), (b, S, N))
    C = jax.random.normal(jax.random.fold_in(key, 4), (b, S, N))
    D = jnp.ones((H,))
    y1, s1 = ref.ssd(x, dt, a, B, C, D)
    y2, s2 = ssd_scan.ssd_chunked(x, dt, a, B, C, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


def test_ssd_decode_matches_sequence():
    """Step-by-step recurrence == full-sequence SSD."""
    key = jax.random.PRNGKey(6)
    b, S, H, P, N = 1, 8, 2, 4, 8
    x = jax.random.normal(key, (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (b, S, H)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.1)
    B = jax.random.normal(jax.random.fold_in(key, 3), (b, S, N))
    C = jax.random.normal(jax.random.fold_in(key, 4), (b, S, N))
    D = jnp.zeros((H,))
    y_full, state_full = ref.ssd(x, dt, a, B, C, D)
    state = jnp.zeros((b, H, P, N))
    ys = []
    for t in range(S):
        y, state = ref.ssd_decode_step(x[:, t], dt[:, t], a, B[:, t], C[:, t],
                                       D, state)
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state_full), np.asarray(state),
                               rtol=1e-4, atol=1e-4)


def test_blocked_sdpa_equals_dense():
    from repro import flags
    key = jax.random.PRNGKey(7)
    S, H, K, D = 2048, 2, 2, 64
    q = jax.random.normal(key, (1, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, S, K, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, S, K, D))
    pos = jnp.arange(S)[None]
    blocked = ref.sdpa(q, k, v, q_positions=pos, kv_positions=pos)
    with flags.override(dense_sdpa=True):
        dense = ref.sdpa(q, k, v, q_positions=pos, kv_positions=pos)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)


def test_ops_backend_switch():
    key = jax.random.PRNGKey(8)
    q = jax.random.normal(key, (1, 128, 4, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 128, 2, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 128, 2, 64))
    pos = jnp.arange(128)[None]
    a = ops.sdpa(q, k, v, q_positions=pos, kv_positions=pos)
    ops.set_backend("pallas_interpret")
    try:
        b = ops.sdpa(q, k, v, q_positions=pos, kv_positions=pos)
    finally:
        ops.set_backend("ref")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-5)
