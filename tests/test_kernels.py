"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import cola_fit as ck
from repro.kernels import decode_attention as da
from repro.kernels import flash_attention as fa
from repro.kernels import multi_lora as ml
from repro.kernels import ops, ref, ssd_scan


def _tol(dt):
    return dict(rtol=2e-2, atol=5e-2) if dt == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("S,H,K,D", [(128, 4, 4, 64), (256, 4, 2, 64),
                                     (256, 8, 2, 128), (128, 6, 3, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_fwd_sweep(S, H, K, D, dtype):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, S, H, D), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, S, K, D), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, S, K, D), dtype)
    pos = jnp.arange(S)[None]
    o_ref = ref.sdpa(q, k, v, q_positions=pos, kv_positions=pos)
    o = fa.flash_attention(q, k, v, interpret=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window,softcap", [(None, None), (64, None),
                                            (None, 30.0), (64, 30.0)])
def test_flash_attention_masking_variants(window, softcap):
    key = jax.random.PRNGKey(1)
    S, H, K, D = 256, 4, 2, 64
    q = jax.random.normal(key, (1, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, S, K, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, S, K, D))
    pos = jnp.arange(S)[None]
    o_ref = ref.sdpa(q, k, v, q_positions=pos, kv_positions=pos,
                     window=window, softcap=softcap)
    o = fa.flash_attention(q, k, v, window=window, softcap=softcap,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-5)


def test_flash_attention_backward():
    key = jax.random.PRNGKey(2)
    S, H, K, D = 128, 4, 2, 64
    q = jax.random.normal(key, (1, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, S, K, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, S, K, D))
    pos = jnp.arange(S)[None]

    def loss_ref(q, k, v):
        return jnp.sum(ref.sdpa(q, k, v, q_positions=pos,
                                kv_positions=pos) ** 2)

    def loss_ker(q, k, v):
        return jnp.sum(fa.flash_attention(q, k, v, interpret=True) ** 2)

    g1 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ker, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=2e-4)


@pytest.mark.parametrize("T,din,dout,r", [(256, 128, 128, 8), (512, 192, 96, 16),
                                          (128, 64, 256, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cola_fit_sweep(T, din, dout, r, dtype):
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (T, din), dtype)
    g = jax.random.normal(jax.random.fold_in(key, 1), (T, dout), dtype) * 0.01
    A = jax.random.normal(jax.random.fold_in(key, 2), (din, r), jnp.float32)
    B = jax.random.normal(jax.random.fold_in(key, 3), (r, dout), jnp.float32)
    dA1, dB1 = ref.cola_fit_lowrank(x, g, A, B, scale=1.0)
    dA2, dB2 = ck.cola_fit_lowrank(x, g, A, B, scale=1.0, interpret=True)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dA1), np.asarray(dA2), **tol)
    np.testing.assert_allclose(np.asarray(dB1), np.asarray(dB2), **tol)


# ---------------------------------------------------------------------------
# fused single-query decode attention (serving hot path)
# ---------------------------------------------------------------------------

def _decode_case(key, B, Smax, H, K, D, dtype=jnp.float32, seed_positions=None):
    q = jax.random.normal(key, (B, 1, H, D), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Smax, K, D), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Smax, K, D), dtype)
    if seed_positions is None:
        positions = jax.random.randint(jax.random.fold_in(key, 3), (B,),
                                       0, Smax)
    else:
        positions = jnp.asarray(seed_positions, jnp.int32)
    return q, k, v, positions


@pytest.mark.parametrize("H,K,D", [(4, 4, 64), (4, 2, 64), (8, 2, 128),
                                   (6, 1, 64), (4, 4, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_gqa_sweep(H, K, D, dtype):
    """Continuous-batching shapes: per-row positions scattered over the cache,
    every GQA group-count flavor (MHA, grouped, MQA)."""
    key = jax.random.PRNGKey(10)
    q, k, v, pos = _decode_case(key, B=4, Smax=128, H=H, K=K, D=D, dtype=dtype)
    o_ref = ref.sdpa_decode(q, k, v, pos)
    o = da.decode_attention(q, k, v, pos, interpret=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window,softcap", [(64, None), (None, 30.0),
                                            (64, 30.0), (16, 10.0)])
def test_decode_attention_masking_variants(window, softcap):
    key = jax.random.PRNGKey(11)
    q, k, v, pos = _decode_case(key, B=4, Smax=256, H=4, K=2, D=64,
                                seed_positions=[0, 17, 100, 255])
    o_ref = ref.sdpa_decode(q, k, v, pos, window=window, softcap=softcap)
    o = da.decode_attention(q, k, v, pos, window=window, softcap=softcap,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-5)


def test_decode_attention_live_mask_zeroes_dead_rows():
    """Dead slots produce exact zeros; live rows are untouched by the mask."""
    key = jax.random.PRNGKey(12)
    q, k, v, pos = _decode_case(key, B=6, Smax=128, H=4, K=2, D=64)
    live = jnp.asarray([True, False, True, False, False, True])
    o_ref = ref.sdpa_decode(q, k, v, pos, live=live)
    o = da.decode_attention(q, k, v, pos, live=live, interpret=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-5)
    assert np.all(np.asarray(o)[~np.asarray(live)] == 0.0)
    o_all = da.decode_attention(q, k, v, pos, interpret=True)
    np.testing.assert_array_equal(np.asarray(o)[np.asarray(live)],
                                  np.asarray(o_all)[np.asarray(live)])


def test_decode_attention_position_zero_and_full_cache():
    """Boundary positions: a row attending to a single KV entry (pos 0) and a
    row at the last cache position both match the oracle."""
    key = jax.random.PRNGKey(13)
    q, k, v, pos = _decode_case(key, B=2, Smax=64, H=4, K=2, D=64,
                                seed_positions=[0, 63])
    o_ref = ref.sdpa_decode(q, k, v, pos)
    o = da.decode_attention(q, k, v, pos, interpret=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-5)


def test_ops_sdpa_decode_backend_switch():
    key = jax.random.PRNGKey(14)
    q, k, v, pos = _decode_case(key, B=3, Smax=128, H=4, K=2, D=64)
    a = ops.sdpa_decode(q, k, v, pos)
    ops.set_backend("pallas_interpret")
    try:
        b = ops.sdpa_decode(q, k, v, pos)
    finally:
        ops.set_backend("ref")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("T,U,din,dout,r", [(128, 2, 64, 64, 4),
                                            (256, 8, 128, 96, 8),
                                            (64, 3, 192, 128, 16)])
def test_multi_lora_sweep(T, U, din, dout, r):
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (T, din))
    A = jax.random.normal(jax.random.fold_in(key, 1), (U, din, r))
    B = jax.random.normal(jax.random.fold_in(key, 2), (U, r, dout))
    idx = jax.random.randint(jax.random.fold_in(key, 3), (T,), 0, U)
    y1 = ref.multi_lora(x, A, B, idx, scale=0.5)
    y2 = ml.multi_lora(x, A, B, idx, scale=0.5, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# grouped decode dispatch + int8-stored banks
# ---------------------------------------------------------------------------

def _lora_bank(key, U, din, r, dout):
    A = jax.random.normal(jax.random.fold_in(key, 1), (U, din, r))
    B = jax.random.normal(jax.random.fold_in(key, 2), (U, r, dout))
    return A, B


def test_compact_resident_remaps_and_pads():
    idx = jnp.asarray([7, 3, 7, -1, 42, 3], jnp.int32)
    resident, remapped = ml.compact_resident(idx, n_users=100)
    res = np.asarray(resident)
    assert list(res[:3]) == [3, 7, 42]
    assert np.all(res[3:] == 100)              # padded with the sentinel
    np.testing.assert_array_equal(np.asarray(remapped), [1, 0, 1, -1, 2, 0])


@pytest.mark.parametrize("dist", ["skewed", "uniform", "single"])
def test_multi_lora_grouped_big_bank(dist):
    """Bank far larger than the decode batch (the BGMV regime): compaction to
    the resident set must be exact across adapter distributions, including
    idx == -1 padding rows."""
    key = jax.random.PRNGKey(15)
    T, U, din, r, dout = 64, 300, 64, 8, 96
    x = jax.random.normal(key, (T, din))
    A, B = _lora_bank(key, U, din, r, dout)
    rng = np.random.default_rng(0)
    if dist == "skewed":      # most rows on 3 adapters + padding rows
        idx = rng.choice([5, 191, 250], size=T).astype(np.int32)
        idx[::9] = -1
    elif dist == "uniform":
        idx = rng.integers(0, U, size=T).astype(np.int32)
    else:                     # every row on one adapter
        idx = np.full(T, 123, np.int32)
    idx = jnp.asarray(idx)
    y1 = ref.multi_lora(x, A, B, idx, scale=0.5)
    y2 = ml.multi_lora_grouped(x, A, B, idx, scale=0.5, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def test_multi_lora_grouped_single_adapter_fast_path():
    """U == 1 skips compaction entirely; idx != 0 rows still mask to zero."""
    key = jax.random.PRNGKey(16)
    T, din, r, dout = 64, 64, 4, 64
    x = jax.random.normal(key, (T, din))
    A, B = _lora_bank(key, 1, din, r, dout)
    idx = jnp.asarray(([0] * 60 + [-1] * 4), jnp.int32)
    y1 = ref.multi_lora(x, A, B, idx)
    y2 = ml.multi_lora_grouped(x, A, B, idx, scale=1.0, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def test_ops_multi_lora_routes_grouped_when_bank_exceeds_batch():
    """ops.multi_lora must produce oracle results through the grouped path
    (U > T) under the interpret backend, including unsupported-shape fallback."""
    key = jax.random.PRNGKey(17)
    T, U, din, r, dout = 32, 100, 64, 8, 64
    x = jax.random.normal(key, (T, din))
    A, B = _lora_bank(key, U, din, r, dout)
    idx = jnp.asarray(np.random.default_rng(1).integers(-1, U, T), jnp.int32)
    want = ref.multi_lora(x, A, B, idx)
    ops.set_backend("pallas_interpret")
    try:
        got = ops.multi_lora(x, A, B, idx)
    finally:
        ops.set_backend("ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_multi_lora_idx_minus_one_rows_are_exact_zero():
    key = jax.random.PRNGKey(18)
    x = jax.random.normal(key, (64, 64))
    A, B = _lora_bank(key, 4, 64, 4, 64)
    idx = jnp.asarray([-1] * 64, jnp.int32)
    for y in (ref.multi_lora(x, A, B, idx),
              ml.multi_lora(x, A, B, idx, interpret=True),
              ml.multi_lora_grouped(x, A, B, idx, scale=1.0, interpret=True)):
        assert np.all(np.asarray(y) == 0.0)


def test_quant_rows_roundtrip_error_bound():
    """Per-row symmetric int8: reconstruction error bounded by scale/2 per
    element (half a quantisation step)."""
    key = jax.random.PRNGKey(19)
    w = jax.random.normal(key, (4, 32, 8)) * 3.0
    q, s = ml.quant_rows(w)
    assert q.dtype == jnp.int8 and s.shape == (4, 32, 1)
    recon = q.astype(jnp.float32) * s
    assert float(jnp.max(jnp.abs(recon - w) / s)) <= 0.5 + 1e-6


@pytest.mark.parametrize("T,U,din,dout,r", [(128, 4, 64, 64, 4),
                                            (64, 8, 128, 96, 8)])
def test_multi_lora_q8_matches_oracle(T, U, din, dout, r):
    key = jax.random.PRNGKey(20)
    x = jax.random.normal(key, (T, din))
    A, B = _lora_bank(key, U, din, r, dout)
    A_q, A_s = ml.quant_rows(A)
    B_q, B_s = ml.quant_rows(B)
    idx = np.random.default_rng(2).integers(0, U, T).astype(np.int32)
    idx[::13] = -1
    idx = jnp.asarray(idx)
    y1 = ref.multi_lora_q8(x, A_q, A_s, B_q, B_s, idx, scale=0.5)
    y2 = ml.multi_lora_q8(x, A_q, A_s, B_q, B_s, idx, scale=0.5,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    # quantisation itself stays within ~1% of the f32 bank apply
    truth = ref.multi_lora(x, A, B, idx, scale=0.5)
    denom = float(jnp.abs(truth).max()) + 1e-9
    assert float(jnp.abs(np.asarray(y1) - np.asarray(truth)).max()) / denom < 0.02


@pytest.mark.parametrize("S,chunk", [(256, 64), (96, 32), (512, 128),
                                     (200, 64), (130, 128)])
def test_ssd_chunked_matches_quadratic(S, chunk):
    key = jax.random.PRNGKey(5)
    b, H, P, N = 2, 4, 16, 8
    x = jax.random.normal(key, (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (b, S, H)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.1)
    B = jax.random.normal(jax.random.fold_in(key, 3), (b, S, N))
    C = jax.random.normal(jax.random.fold_in(key, 4), (b, S, N))
    D = jnp.ones((H,))
    y1, s1 = ref.ssd(x, dt, a, B, C, D)
    y2, s2 = ssd_scan.ssd_chunked(x, dt, a, B, C, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("S,chunk", [(200, 64), (37, 32), (300, 128)])
def test_ssd_chunked_tail_state_matches_decode(S, chunk):
    """Non-divisible lengths: the state returned by the chunked scan must be
    exactly the state after position S (the tail chunk is sliced at its true
    length, never padded), as produced by the step-by-step decode recurrence."""
    key = jax.random.PRNGKey(9)
    b, H, P, N = 2, 3, 8, 4
    x = jax.random.normal(key, (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (b, S, H)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.1)
    B = jax.random.normal(jax.random.fold_in(key, 3), (b, S, N))
    C = jax.random.normal(jax.random.fold_in(key, 4), (b, S, N))
    D = jnp.ones((H,))
    y_chunked, s_chunked = ssd_scan.ssd_chunked(x, dt, a, B, C, D, chunk=chunk)
    state = jnp.zeros((b, H, P, N))
    ys = []
    for t in range(S):
        y, state = ref.ssd_decode_step(x[:, t], dt[:, t], a, B[:, t], C[:, t],
                                       D, state)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(s_chunked), np.asarray(state),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_chunked),
                               np.asarray(jnp.stack(ys, axis=1)),
                               rtol=1e-4, atol=1e-4)


def test_ssd_decode_matches_sequence():
    """Step-by-step recurrence == full-sequence SSD."""
    key = jax.random.PRNGKey(6)
    b, S, H, P, N = 1, 8, 2, 4, 8
    x = jax.random.normal(key, (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (b, S, H)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.1)
    B = jax.random.normal(jax.random.fold_in(key, 3), (b, S, N))
    C = jax.random.normal(jax.random.fold_in(key, 4), (b, S, N))
    D = jnp.zeros((H,))
    y_full, state_full = ref.ssd(x, dt, a, B, C, D)
    state = jnp.zeros((b, H, P, N))
    ys = []
    for t in range(S):
        y, state = ref.ssd_decode_step(x[:, t], dt[:, t], a, B[:, t], C[:, t],
                                       D, state)
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state_full), np.asarray(state),
                               rtol=1e-4, atol=1e-4)


def test_blocked_sdpa_equals_dense():
    from repro import flags
    key = jax.random.PRNGKey(7)
    S, H, K, D = 2048, 2, 2, 64
    q = jax.random.normal(key, (1, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, S, K, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, S, K, D))
    pos = jnp.arange(S)[None]
    blocked = ref.sdpa(q, k, v, q_positions=pos, kv_positions=pos)
    with flags.override(dense_sdpa=True):
        dense = ref.sdpa(q, k, v, q_positions=pos, kv_positions=pos)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)


def test_ops_backend_switch():
    key = jax.random.PRNGKey(8)
    q = jax.random.normal(key, (1, 128, 4, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 128, 2, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 128, 2, 64))
    pos = jnp.arange(128)[None]
    a = ops.sdpa(q, k, v, q_positions=pos, kv_positions=pos)
    ops.set_backend("pallas_interpret")
    try:
        b = ops.sdpa(q, k, v, q_positions=pos, kv_positions=pos)
    finally:
        ops.set_backend("ref")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-5)
