"""Perf-baseline trajectory: save/load roundtrip, comparator direction and
noise-floor semantics, and schema sanity of the committed BENCH_*.json files."""
import json
import os
import sys

import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO_ROOT)

from benchmarks import perf_baseline as pb  # noqa: E402


def _doc(entries):
    return {"version": 1, "meta": {}, "entries": entries}


def test_save_load_roundtrip(tmp_path):
    entries = [pb.entry("op_a", "S=64", median_ms=1.5, p90_ms=2.0),
               pb.entry("op_b", "slots=4", tokens_per_s=1234.5)]
    path = str(tmp_path / "bench.json")
    pb.save(path, entries, meta={"suite": "unit"})
    doc = pb.load(path)
    assert doc["version"] == 1 and doc["meta"] == {"suite": "unit"}
    assert doc["entries"] == entries


def test_entry_rejects_unknown_metric():
    with pytest.raises(AssertionError):
        pb.entry("op", "shape", bogus_metric=1.0)


def test_compare_flags_walltime_regression_and_throughput_drop():
    base = _doc([pb.entry("k", "s", median_ms=10.0, p90_ms=12.0),
                 pb.entry("serve", "s", tokens_per_s=1000.0)])
    cur = [pb.entry("k", "s", median_ms=20.0, p90_ms=12.5),
           pb.entry("serve", "s", tokens_per_s=400.0)]
    diff = pb.compare(base, cur, threshold=0.35)
    flagged = {(r["op"], r["metric"]) for r in diff["regressions"]}
    assert flagged == {("k", "median_ms"), ("serve", "tokens_per_s")}
    assert not diff["improvements"] and not diff["missing"] and not diff["new"]


def test_compare_flags_improvements_not_regressions():
    base = _doc([pb.entry("k", "s", median_ms=10.0),
                 pb.entry("serve", "s", tokens_per_s=1000.0)])
    cur = [pb.entry("k", "s", median_ms=4.0),
           pb.entry("serve", "s", tokens_per_s=2000.0)]
    diff = pb.compare(base, cur, threshold=0.35)
    assert not diff["regressions"] and len(diff["improvements"]) == 2


def test_compare_ignores_subfloor_walltime_noise():
    """A 100% relative change on a 50us op is timer noise, not a regression
    (the absolute delta floor); the same relative change above the floor is."""
    base = _doc([pb.entry("tiny", "s", median_ms=0.05)])
    diff = pb.compare(base, [pb.entry("tiny", "s", median_ms=0.10)],
                      threshold=0.35)
    assert not diff["regressions"]
    base = _doc([pb.entry("big", "s", median_ms=5.0)])
    diff = pb.compare(base, [pb.entry("big", "s", median_ms=10.0)],
                      threshold=0.35)
    assert len(diff["regressions"]) == 1


def test_compare_reports_missing_and_new_entries():
    base = _doc([pb.entry("gone", "s", median_ms=1.0)])
    diff = pb.compare(base, [pb.entry("fresh", "s", median_ms=1.0)])
    assert diff["missing"] == [("gone", "s")]
    assert diff["new"] == [("fresh", "s")]


@pytest.mark.parametrize("name", ["BENCH_kernels.json", "BENCH_serve.json"])
def test_committed_baselines_are_wellformed(name):
    path = os.path.join(REPO_ROOT, name)
    assert os.path.exists(path), f"{name} must be committed at the repo root"
    doc = pb.load(path)
    assert doc["version"] == 1 and doc["entries"]
    for e in doc["entries"]:
        assert set(e) == {"op", "shape", "metrics"}
        assert e["metrics"] and all(
            k in pb.METRIC_DIRECTION and v > 0 for k, v in e["metrics"].items())
    # self-compare is a no-op: the committed baseline never regresses vs itself
    diff = pb.compare(doc, doc["entries"])
    assert not diff["regressions"] and not diff["missing"] and not diff["new"]


def test_committed_serve_baseline_shows_burst_speedup():
    """The PR's decode speed pass must be visible in the committed trajectory:
    burst decoding beats tick-at-a-time decode tokens/sec on this host."""
    doc = pb.load(os.path.join(REPO_ROOT, "BENCH_serve.json"))
    rows = {e["shape"]: e["metrics"]["tokens_per_s"]
            for e in doc["entries"] if e["op"] == "serve_decode"}
    assert rows["slots=4,users=2,burst=8"] > rows["slots=4,users=2,burst=1"]
