"""Serving engine: batched prefill == single-row reference (logits and
tokens, with and without per-user adapters), slot-mask isolation (admission
must not perturb live slots), and engine stats consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ColaConfig
from repro.core import gl
from repro.models import model as M
from repro.runtime.serve_loop import Request, ServeEngine, _bucket


def _tiny():
    cfg = registry.reduced_config("smollm-135m").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=128)
    key = jax.random.PRNGKey(0)
    return cfg, M.init(cfg, key), key


def _banks(cfg, key):
    cc = ColaConfig(mode="lora", family="lowrank", taps="qv", rank=4)
    ad0 = gl.init_adapters(cfg, cc, jax.random.fold_in(key, 1))
    ad1 = gl.init_adapters(cfg, cc, jax.random.fold_in(key, 2))
    ad1 = jax.tree.map(lambda a: a + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 3), a.shape), ad1)
    return [ad0, ad1]


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=p) for p in lens]


# ---------------------------------------------------------------------------
# batched prefill == token-by-token reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("with_adapters", [False, True])
def test_batched_prefill_matches_reference_tokens(with_adapters):
    """Per-slot generated tokens identical between the one-shot padded batched
    prefill and the token-by-token single-row reference, across mixed prompt
    lengths (including length-1 prompts, which skip prefill entirely)."""
    cfg, params, key = _tiny()
    banks = _banks(cfg, key) if with_adapters else None
    prompts = _prompts(cfg, (1, 5, 9, 13))
    outs = {}
    for mode in ("batched", "reference"):
        eng = ServeEngine(cfg, params, slots=4, max_len=64,
                          user_adapters=banks, prefill_mode=mode)
        reqs = [Request(rid=i, user=i % 2, prompt=p, max_new=6)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_idle()
        outs[mode] = [r.out for r in reqs]
    assert outs["batched"] == outs["reference"]


def test_batched_prefill_matches_reference_logits():
    """Model-level: scatter-prefill into a slot cache, then one decode step —
    logits match feeding the prompt token-by-token through the live-masked
    decode path (the engine's two prefill modes, minus the engine)."""
    cfg, params, key = _tiny()
    slots, max_len = 3, 32
    prompts = _prompts(cfg, (7, 4))
    slot_ids = np.array([0, 2], np.int32)

    # reference: per-token decode with a single-slot live mask
    cache_ref = M.init_cache(cfg, slots, max_len)
    for j, prompt in enumerate(prompts):
        s = slot_ids[j]
        for t, tok in enumerate(prompt[:-1]):
            toks = np.zeros((slots, 1), np.int32)
            toks[s, 0] = tok
            pos = np.zeros((slots,), np.int32)
            pos[s] = t
            live = np.zeros((slots,), bool)
            live[s] = True
            _, cache_ref = M.decode_step(
                cfg, params, {"tokens": jnp.asarray(toks),
                              "positions": jnp.asarray(pos)}, cache_ref,
                live=jnp.asarray(live))

    # batched: one padded prefill scattered into the slot cache
    pmax = max(len(p) for p in prompts) - 1
    toks = np.zeros((len(prompts), pmax), np.int32)
    for j, p in enumerate(prompts):
        toks[j, :len(p) - 1] = p[:-1]
    _, pre = M.prefill(cfg, params, {"tokens": jnp.asarray(toks)})
    cache_bat = M.scatter_prefill_cache(M.init_cache(cfg, slots, max_len),
                                        pre, jnp.asarray(slot_ids))

    # decode the last prompt token for both slots at once; compare logits
    toks = np.zeros((slots, 1), np.int32)
    pos = np.zeros((slots,), np.int32)
    live = np.zeros((slots,), bool)
    for j, p in enumerate(prompts):
        toks[slot_ids[j], 0] = p[-1]
        pos[slot_ids[j]] = len(p) - 1
        live[slot_ids[j]] = True
    batch = {"tokens": jnp.asarray(toks), "positions": jnp.asarray(pos)}
    lg_ref, _ = M.decode_step(cfg, params, batch, cache_ref,
                              live=jnp.asarray(live))
    lg_bat, _ = M.decode_step(cfg, params, batch, cache_bat,
                              live=jnp.asarray(live))
    np.testing.assert_allclose(np.asarray(lg_bat[slot_ids]),
                               np.asarray(lg_ref[slot_ids]),
                               rtol=2e-4, atol=2e-5)


def test_prefill_lengths_gathers_per_row_logits():
    """prefill(lengths=...) on a right-padded batch returns each row's
    unpadded last-token logits."""
    cfg, params, key = _tiny()
    prompts = _prompts(cfg, (4, 7))
    pmax = max(len(p) for p in prompts)
    toks = np.zeros((len(prompts), pmax), np.int32)
    for j, p in enumerate(prompts):
        toks[j, :len(p)] = p
    lengths = jnp.asarray([len(p) for p in prompts], jnp.int32)
    lg, _ = M.prefill(cfg, params, {"tokens": jnp.asarray(toks)},
                      lengths=lengths)
    for j, p in enumerate(prompts):
        lg_solo, _ = M.prefill(cfg, params,
                               {"tokens": jnp.asarray(p[None, :])})
        np.testing.assert_allclose(np.asarray(lg[j, 0]),
                                   np.asarray(lg_solo[0, 0]),
                                   rtol=2e-4, atol=2e-5)


def test_batched_prefill_matches_reference_ssm():
    """Recurrent-state models must prefill each row at its exact length (a
    right-padded batch would fold pad tokens into the final ssm/conv state).
    Regression: batched == reference tokens on an SSM config with mixed
    prompt lengths that would otherwise hit different pad buckets."""
    cfg = registry.reduced_config("mamba2-370m").replace(
        n_layers=2, d_model=64, vocab_size=128)
    params = M.init(cfg, jax.random.PRNGKey(0))
    assert M.has_recurrent_state(cfg)
    prompts = _prompts(cfg, (3, 6, 11))
    outs = {}
    for mode in ("batched", "reference"):
        eng = ServeEngine(cfg, params, slots=3, max_len=32, prefill_mode=mode)
        reqs = [Request(rid=i, user=0, prompt=p, max_new=4)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_idle()
        outs[mode] = [r.out for r in reqs]
    assert outs["batched"] == outs["reference"]


def test_prefill_bucket_capped_at_max_len():
    """A prompt whose pad bucket exceeds max_len must still prefill (the
    bucket is clamped to the cache's sequence axis)."""
    cfg, params, key = _tiny()
    eng = ServeEngine(cfg, params, slots=2, max_len=100)
    prompt = _prompts(cfg, (70,))[0]
    req = Request(rid=0, user=0, prompt=prompt, max_new=3)
    eng.submit(req)
    eng.run_until_idle()
    assert req.done and len(req.out) == 3


# ---------------------------------------------------------------------------
# slot isolation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["batched", "reference"])
def test_admission_mid_flight_leaves_live_slots_bit_identical(mode):
    """Admitting a request while others decode must not change their output."""
    cfg, params, key = _tiny()
    prompts = _prompts(cfg, (9, 6))

    def run(second_request: bool):
        eng = ServeEngine(cfg, params, slots=2, max_len=64, prefill_mode=mode,
                          user_adapters=_banks(cfg, key))
        r0 = Request(rid=0, user=0, prompt=prompts[0], max_new=10)
        eng.submit(r0)
        for _ in range(3):
            eng.tick()
        if second_request:
            eng.submit(Request(rid=1, user=1, prompt=prompts[1], max_new=4))
        eng.run_until_idle()
        return r0.out

    assert run(False) == run(True)


def test_feed_does_not_clobber_other_slots():
    """The single-row reference prefill must only write its target slot's
    cache row (regression: the unmasked version wrote token 0 at position 0
    of every other slot)."""
    cfg, params, key = _tiny()
    eng = ServeEngine(cfg, params, slots=3, max_len=32,
                      prefill_mode="reference")
    for t in range(4):
        eng._feed(1, 5 + t, t)
    k = np.asarray(eng.cache["layers"]["k"])   # (L, slots, max_len, K, Dh)
    assert np.all(k[:, 0] == 0) and np.all(k[:, 2] == 0), \
        "non-target slot cache rows were written"
    assert np.any(k[:, 1, :4] != 0), "target slot cache row was not written"


def test_scatter_prefill_cache_drops_out_of_range_rows():
    """Padding rows of a bucketed prefill batch carry slot id == slots and
    must be dropped, not wrapped or clamped onto a real slot."""
    cfg, params, key = _tiny()
    slots, max_len = 2, 32
    cache = M.init_cache(cfg, slots, max_len)
    toks = jnp.asarray(_prompts(cfg, (8,))[0][None, :].astype(np.int32))
    _, pre = M.prefill(cfg, params, {"tokens": toks})
    out = M.scatter_prefill_cache(cache, pre,
                                  jnp.asarray(np.array([slots], np.int32)))
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# stats / admission batching
# ---------------------------------------------------------------------------

def test_engine_stats_consistency():
    cfg, params, key = _tiny()
    prompts = _prompts(cfg, (5, 8, 3, 6, 4))
    eng = ServeEngine(cfg, params, slots=2, max_len=64, admit_batch=2)
    reqs = [Request(rid=i, user=0, prompt=p, max_new=4)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    assert eng.stats["completed"] == len(prompts)
    assert eng.stats["admitted"] == len(prompts)
    assert eng.stats["tokens"] == sum(len(r.out) for r in reqs) == 4 * len(prompts)
    # the full prompt goes through prefill; the first generated token is
    # emitted from the prefill logits, the rest from decode ticks
    assert eng.stats["prefill_tokens"] == sum(len(p) for p in prompts)
    assert eng.stats["decode_tokens"] == eng.stats["tokens"] - len(prompts)
    # 2 slots, 5 requests x 3 decode tokens (the first of the 4 comes from
    # prefill) -> at least ceil(15/2) decode ticks
    assert eng.stats["ticks"] >= 8
    stats = eng.request_stats()
    assert len(stats) == len(prompts)
    for s in stats:
        assert s["new_tokens"] == 4
        assert s["ttft"] is not None and s["latency"] is not None
        assert 0 <= s["ttft"] <= s["latency"]
    th = eng.throughput()
    assert th["decode_tok_per_s"] > 0 and th["prefill_tok_per_s"] > 0
    assert th["completed"] == len(prompts)


def test_admit_batch_caps_admission():
    cfg, params, key = _tiny()
    eng = ServeEngine(cfg, params, slots=4, max_len=64, admit_batch=1)
    for i, p in enumerate(_prompts(cfg, (4, 4, 4))):
        eng.submit(Request(rid=i, user=0, prompt=p, max_new=6))
    eng.tick()
    assert sum(r is not None for r in eng.active) == 1
    eng.tick()
    assert sum(r is not None for r in eng.active) == 2
    eng.run_until_idle()
    assert eng.stats["completed"] == 3


def test_bucket_rounds_up_to_power_of_two():
    assert _bucket(1) == 8 and _bucket(8) == 8 and _bucket(9) == 16
    assert _bucket(100) == 128 and _bucket(3, floor=1) == 4


# ---------------------------------------------------------------------------
# admission-time request validation
# ---------------------------------------------------------------------------

def test_submit_rejects_invalid_requests():
    """Bad requests get a terminal rejected status without ever occupying a
    slot or crashing a tick; valid ones are unaffected."""
    cfg, params, key = _tiny()
    eng = ServeEngine(cfg, params, slots=2, max_len=16,
                      user_adapters=_banks(cfg, key))
    bad = [
        Request(rid=0, user=0, prompt=np.array([], np.int32), max_new=4),
        Request(rid=1, user=0, prompt=np.arange(16) % cfg.vocab_size,
                max_new=4),                                    # > max_len - 1
        Request(rid=2, user=0, prompt=np.arange(4), max_new=0),
        Request(rid=3, user=0, prompt=np.arange(4), max_new=-2),
        Request(rid=4, user=7, prompt=np.arange(4), max_new=4),  # unknown user
    ]
    for r in bad:
        eng.submit(r)
    assert not eng.queue and all(r is None for r in eng.active)
    assert eng.stats["rejected"] == len(bad)
    assert len(eng.finished) == len(bad)
    for r in bad:
        assert r.done and r.status.startswith("rejected: ")
        assert r.out == [] and r.latency is not None
    assert "empty prompt" in bad[0].status
    assert "prompt length" in bad[1].status
    assert "max_new" in bad[2].status and "max_new" in bad[3].status
    assert "unknown user" in bad[4].status

    ok = Request(rid=5, user=1, prompt=np.arange(5) % cfg.vocab_size, max_new=3)
    eng.submit(ok)
    eng.run_until_idle()
    assert ok.status == "done" and len(ok.out) == 3
    assert eng.stats["completed"] == 1


def test_submit_without_bank_accepts_any_user_id():
    """With no adapter bank configured there is no user routing to validate."""
    cfg, params, _ = _tiny()
    eng = ServeEngine(cfg, params, slots=1, max_len=32)
    r = Request(rid=0, user=99, prompt=np.arange(4) % cfg.vocab_size, max_new=2)
    eng.submit(r)
    eng.run_until_idle()
    assert r.status == "done" and eng.stats["rejected"] == 0


# ---------------------------------------------------------------------------
# burst decoding
# ---------------------------------------------------------------------------

def _run_engine(cfg, params, banks, prompts, max_new, **kw):
    eng = ServeEngine(cfg, params, slots=len(prompts), max_len=64,
                      user_adapters=banks, **kw)
    reqs = [Request(rid=i, user=i % 2 if banks else 0, prompt=p,
                    max_new=max_new) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    return [r.out for r in reqs], eng


@pytest.mark.parametrize("with_adapters", [False, True])
def test_burst_decode_tokens_bit_identical(with_adapters):
    """decode_burst=N fuses ticks into one lax.scan; emitted tokens must be
    bit-identical to tick-at-a-time decoding (max_new=17 forces uneven burst
    splits: 8+4+2+1 plus the TTFT-protected first tick)."""
    cfg, params, key = _tiny()
    banks = _banks(cfg, key) if with_adapters else None
    prompts = _prompts(cfg, (5, 9, 13))
    o1, e1 = _run_engine(cfg, params, banks, prompts, max_new=17)
    o2, e2 = _run_engine(cfg, params, banks, prompts, max_new=17,
                         decode_burst=8)
    assert o1 == o2
    assert e2.stats["tokens"] == e1.stats["tokens"]
    assert all(len(o) == 17 for o in o2)


def test_burst_decode_staggered_completion():
    """Mixed max_new across slots: bursts must shrink to the soonest
    completion so no slot ever overruns its budget."""
    cfg, params, key = _tiny()
    prompts = _prompts(cfg, (5, 9))
    eng = ServeEngine(cfg, params, slots=2, max_len=64, decode_burst=16)
    r0 = Request(rid=0, user=0, prompt=prompts[0], max_new=3)
    r1 = Request(rid=1, user=0, prompt=prompts[1], max_new=21)
    eng.submit(r0)
    eng.submit(r1)
    eng.run_until_idle()
    assert len(r0.out) == 3 and len(r1.out) == 21
    ref_eng = ServeEngine(cfg, params, slots=2, max_len=64)
    q0 = Request(rid=0, user=0, prompt=prompts[0], max_new=3)
    q1 = Request(rid=1, user=0, prompt=prompts[1], max_new=21)
    ref_eng.submit(q0)
    ref_eng.submit(q1)
    ref_eng.run_until_idle()
    assert r0.out == q0.out and r1.out == q1.out


# ---------------------------------------------------------------------------
# int8-stored adapter banks
# ---------------------------------------------------------------------------

def _dequant_banks(banks):
    from repro.kernels.multi_lora import quant_rows
    out = []
    for a in banks:
        d = {}
        for tap, leaves in a.items():
            d[tap] = {}
            for n, leaf in leaves.items():
                q, s = quant_rows(leaf)
                d[tap][n] = (q.astype(jnp.float32) * s).astype(leaf.dtype)
        out.append(d)
    return out


def test_int8_bank_matches_dequantized_f32_serving():
    """bank_store="int8" must emit exactly the tokens of serving the
    explicitly round-tripped (dequantised) f32 bank — the int8 path changes
    storage and load, never math."""
    cfg, params, key = _tiny()
    banks = _banks(cfg, key)
    prompts = _prompts(cfg, (5, 9, 13))
    o_q8, e_q8 = _run_engine(cfg, params, banks, prompts, max_new=8,
                             bank_store="int8")
    o_f32, _ = _run_engine(cfg, params, _dequant_banks(banks), prompts,
                           max_new=8)
    assert o_q8 == o_f32
    # the stored bank is int8 codes + f32 scales, never f32 weights
    for tap, leaves in e_q8.bank.items():
        assert set(n.rsplit("_", 1)[-1] for n in leaves) == {"q", "scale"}
        for n, leaf in leaves.items():
            if n.endswith("_q"):
                assert leaf.dtype == jnp.int8


def test_int8_bank_install_adapters_quantizes_incoming():
    """Hot-swapping f32 adapters into an int8 bank quantises on install and
    the swap actually changes served tokens for that user only."""
    cfg, params, key = _tiny()
    banks = _banks(cfg, key)
    prompts = _prompts(cfg, (6, 6))
    eng = ServeEngine(cfg, params, slots=2, max_len=64, user_adapters=banks,
                      bank_store="int8")
    from repro.core import gl
    from repro.configs.base import ColaConfig
    cc = ColaConfig(mode="lora", family="lowrank", taps="qv", rank=4)
    new = gl.init_adapters(cfg, cc, jax.random.fold_in(key, 7))
    new = jax.tree.map(lambda a: a + 0.5, new)
    assert eng.install_adapters(1, new, version=1)
    assert eng.stats["bank_installs"] == 1
    for tap, leaves in eng.bank.items():
        for n, leaf in leaves.items():
            if n.endswith("_q"):
                assert leaf.dtype == jnp.int8
    # stale version is still rejected on the q8 path
    assert not eng.install_adapters(1, new, version=1)
    assert eng.stats["bank_rejected"] == 1


# ---------------------------------------------------------------------------
# decode kernel switch (ref backend vs fused interpret kernels)
# ---------------------------------------------------------------------------

def test_decode_tokens_identical_across_kernel_backends():
    """End-to-end engine regression for the fused decode kernels: tokens under
    the pallas_interpret backend (fused decode attention + grouped multi-LoRA)
    match the jnp reference backend exactly. Uses d_head=64 so the decode
    attention kernel's support gate engages."""
    from repro.kernels import ops
    cfg, params, key = _tiny()
    cfg = cfg.replace(n_heads=2, n_kv_heads=1, d_head=64)
    params = M.init(cfg, jax.random.PRNGKey(0))
    banks = _banks(cfg, key)
    prompts = _prompts(cfg, (5, 9))
    o_ref, _ = _run_engine(cfg, params, banks, prompts, max_new=5)
    ops.set_backend("pallas_interpret")
    try:
        o_int, _ = _run_engine(cfg, params, banks, prompts, max_new=5)
    finally:
        ops.set_backend("ref")
    assert o_ref == o_int
