"""Offloader ``compress="int8"`` transfer path: quantisation round-trip error
bound, ``pushed_bytes`` accounting, and fit equivalence against the exact
(uncompressed) transfer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ColaConfig
from repro.core import gl
from repro.core.offload import Offloader, dequant_int8, quant_int8
from repro.core.session import ColaSession
from repro.data.pipeline import SyntheticLM
from repro.models import model as M
from repro.optim import optimizers as opt


def _mk():
    cfg = registry.reduced_config("smollm-135m").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=128)
    key = jax.random.PRNGKey(0)
    return cfg, M.init(cfg, key), key


# ---------------------------------------------------------------------------
# quant/dequant round trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(5, 33), (2, 4, 16, 64)])
def test_int8_roundtrip_error_bound(shape):
    """Symmetric per-row int8: |x - dq(q(x))| <= scale/2 elementwise, with
    scale = rowmax|x| / 127 — i.e. worst-case relative error ~0.4% of the
    row's max magnitude."""
    x = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32) * 3.0
    q, scale = quant_int8(x)
    assert q.dtype == jnp.int8
    assert scale.shape == shape[:-1] + (1,)
    err = np.abs(np.asarray(dequant_int8(q, scale) - x))
    bound = np.asarray(scale) / 2.0 + 1e-7
    assert (err <= bound).all()
    # exact at the row extremes (they map to +-127 exactly)
    rows = np.asarray(x).reshape(-1, shape[-1])
    drows = np.asarray(dequant_int8(q, scale)).reshape(-1, shape[-1])
    idx = np.abs(rows).argmax(axis=-1)
    np.testing.assert_allclose(drows[np.arange(len(rows)), idx],
                               rows[np.arange(len(rows)), idx], rtol=1e-5)


def test_int8_zero_and_tiny_rows_are_safe():
    """All-zero rows must not divide by zero; denormal-tiny rows stay finite."""
    x = jnp.stack([jnp.zeros(16), jnp.full(16, 1e-30), jnp.ones(16)])
    q, scale = quant_int8(x)
    out = np.asarray(dequant_int8(q, scale))
    assert np.isfinite(out).all()
    np.testing.assert_array_equal(out[0], np.zeros(16))


# ---------------------------------------------------------------------------
# pushed_bytes accounting
# ---------------------------------------------------------------------------

def _offloaders(cfg, key, compress):
    cc = ColaConfig(mode="faithful_offload", family="lowrank", taps="qv",
                    rank=4, compress=compress)
    from repro.core import taps as taps_lib
    from repro.models import model as model_lib
    taps = gl.select_taps(cfg, cc.taps)
    spec = taps_lib.make_spec(family=cc.family, taps=taps, rank=cc.rank,
                              scale=cc.scale)
    ad = taps_lib.init_adapter_vars(spec, model_lib.tap_sites(cfg), key)
    return Offloader(spec, ad, opt.sgd(0.1), compress=compress), spec


def test_pushed_bytes_accounting():
    """int8 books 1 byte/element + 4 bytes per row scale; "none" books the
    raw payload bytes — and int8 actually compresses (~4x for f32)."""
    cfg, params, key = _mk()
    data = SyntheticLM(cfg, batch=4, seq=16, seed=0)
    batch = data.batch_at(0)
    cc = ColaConfig(mode="faithful_offload", family="lowrank", taps="qv", rank=4)
    spec = gl.make_spec(cfg, cc)
    _, payload, _ = gl.server_step_a(cfg, spec, params,
                                     gl.init_adapters(cfg, cc, key), batch)

    sizes = {}
    for compress in ("none", "int8"):
        off, _ = _offloaders(cfg, key, compress)
        off.push(payload)
        want = 0
        for x, gh in payload.values():
            for a in (x, gh):
                if compress == "int8":
                    q, scale = quant_int8(a)
                    want += int(np.prod(q.shape)) + 4 * int(np.prod(scale.shape))
                else:
                    want += a.size * a.dtype.itemsize
        assert off.stats["pushed_bytes"] == want, compress
        sizes[compress] = off.stats["pushed_bytes"]
    assert sizes["int8"] < sizes["none"] / 3


# ---------------------------------------------------------------------------
# fit equivalence: int8 transfer perturbs, but barely
# ---------------------------------------------------------------------------

def test_int8_fit_close_to_exact():
    cfg, params, key = _mk()
    data = SyntheticLM(cfg, batch=4, seq=16, seed=1)
    sessions = {}
    for compress in ("none", "int8"):
        cc = ColaConfig(mode="faithful_offload", family="lowrank", taps="qv",
                        rank=4, compress=compress)
        sess = ColaSession(cfg, cc, params, key, optimizer=opt.sgd(0.1))
        for t in range(4):
            sess.step(data.batch_at(t))
        sessions[compress] = sess
    exact = np.concatenate([np.asarray(l).ravel() for l in
                            jax.tree.leaves(sessions["none"].adapters)])
    quant = np.concatenate([np.asarray(l).ravel() for l in
                            jax.tree.leaves(sessions["int8"].adapters)])
    assert np.corrcoef(exact, quant)[0, 1] > 0.995
    denom = np.linalg.norm(exact)
    assert denom > 0 and np.linalg.norm(exact - quant) / denom < 0.1
