"""Unified telemetry (ISSUE 10): metric registry, span tracing, flight
recorder and tail-latency histograms across the FTaaS stack.

Acceptance invariants:
- generated tokens are bit-identical telemetry-on vs. telemetry-off, on an
  attention plan (chunked + paged) and an ssm plan (chunked) — telemetry only
  reads host-side values and never touches a jitted computation;
- legacy counters stay exact when mirrored into the registry, and agree
  across engine modes (chunked+paged+burst vs. the batched baseline) for
  everything that counts tokens/requests (tick counts legitimately differ);
- exported traces are valid Chrome-trace-event JSON (schema + per-lane span
  nesting), loadable in Perfetto and parsed by ``repro.trace_summary``;
- the disabled path is zero-cost by construction: shared null context /
  null metric singletons, no tracer, no recorder.

The chaos-side acceptance (quarantine postmortem with failing seq ids) lives
in tests/test_faults.py next to the rest of the chaos suite.
"""
import json
import os
import time

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ColaConfig
from repro.core import gl
from repro.core.session import ColaSession
from repro.data.pipeline import SyntheticLM
from repro.models import model as M
from repro.optim import optimizers as opt
from repro.runtime.serve_loop import Request, ServeEngine
from repro.runtime.train_loop import TrainLoop
from repro.telemetry import NULL_CONTEXT, Telemetry, validate_trace
from repro.telemetry.metrics import (NULL_METRIC, Histogram, MetricRegistry,
                                     percentiles)
from repro.telemetry.recorder import FlightRecorder
from repro.telemetry.tracing import Tracer


def _tiny(name="smollm-135m", **over):
    cfg = registry.reduced_config(name)
    base = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
                d_ff=128, vocab_size=128)
    base.update(over)
    return cfg.replace(**{k: v for k, v in base.items() if hasattr(cfg, k)})


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=p) for p in lens]


# one attention plan (chunked + paged, multi-user banks) and one ssm plan
# (chunked, bankless — qv taps don't exist on the ssm backbone): the two
# cache disciplines the bit-identity guarantee must cover
SERVE_CASES = {
    "smollm-135m": dict(over={}, users=2,
                        kw=dict(prefill_chunk=4, kv_layout="paged",
                                kv_block=8)),
    "mamba2-370m": dict(over=dict(ssm_headdim=16, ssm_state=16), users=0,
                        kw=dict(prefill_chunk=4)),
}


def _serve(name, telemetry=None, lens=(5, 11, 7, 4), max_new=6, slots=2,
           **extra_kw):
    case = SERVE_CASES[name]
    cfg = _tiny(name, **case["over"])
    key = jax.random.PRNGKey(0)
    params = M.init(cfg, key)
    n_users = case["users"]
    banks = None
    if n_users:
        cc = ColaConfig(mode="lora", family="lowrank", taps="qv", rank=4)
        banks = [gl.init_adapters(cfg, cc, jax.random.fold_in(key, u))
                 for u in range(n_users)]
    kw = dict(case["kw"])
    kw.update(extra_kw)
    eng = ServeEngine(cfg, params, slots=slots, max_len=32,
                      user_adapters=banks, telemetry=telemetry, **kw)
    reqs = [Request(rid=i, user=i % max(n_users, 1), prompt=p,
                    max_new=max_new)
            for i, p in enumerate(_prompts(cfg, lens))]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    return eng, [r.out for r in reqs]


# ---------------------------------------------------------------------------
# metric registry units
# ---------------------------------------------------------------------------

def test_percentiles_helper():
    assert percentiles([]) is None
    p = percentiles([1.0, 2.0, 3.0, 4.0])
    assert p["count"] == 4 and p["max"] == 4.0 and p["mean"] == 2.5
    assert p["p50"] == 2.5 and p["p99"] <= 4.0


def test_histogram_exact_then_interpolated():
    h = Histogram(buckets=(1.0, 2.0, 4.0, 8.0), sample_cap=8)
    for v in (0.5, 1.5, 3.0, 7.0):
        h.observe(v)
    # ring still complete: percentiles are exact
    assert h.percentile(50) == pytest.approx(np.percentile([0.5, 1.5, 3.0, 7.0], 50))
    s = h.summary()
    assert s["count"] == 4 and s["min"] == 0.5 and s["max"] == 7.0
    # overflow the ring: interpolation stays within the observed range and
    # monotone in q
    for _ in range(100):
        h.observe(3.0)
    q = [h.percentile(x) for x in (10, 50, 90, 99)]
    assert all(0.0 <= v <= h.max for v in q)
    assert q == sorted(q)
    # beyond the last bound lands in +Inf, never lost
    h.observe(100.0)
    assert h.counts.sum() == h.count


def test_registry_absorb_mirrors_stat_dicts():
    reg = MetricRegistry()
    reg.absorb("serve", {"ticks": 7, "decode_time": 0.5, "ok": True,
                         "label": "skipped", "missing": None,
                         "store": {"hits": 3}})
    snap = reg.snapshot()
    assert snap["serve.ticks"] == 7
    assert snap["serve.decode_time"] == 0.5
    assert snap["serve.ok"] == 1
    assert snap["serve.store.hits"] == 3
    assert "serve.label" not in snap and "serve.missing" not in snap
    # re-absorb keeps the source authoritative (set, not inc)
    reg.absorb("serve", {"ticks": 9})
    assert reg.snapshot()["serve.ticks"] == 9


def test_registry_disabled_is_null():
    reg = MetricRegistry(enabled=False)
    assert reg.counter("a") is NULL_METRIC
    assert reg.gauge("b") is NULL_METRIC
    assert reg.histogram("c") is NULL_METRIC
    reg.absorb("x", {"n": 1})
    assert reg.snapshot() == {}
    reg.emit(step=0)            # no stream, no crash


def test_registry_emit_jsonl(tmp_path):
    reg = MetricRegistry()
    path = str(tmp_path / "telemetry.jsonl")
    reg.stream_to(path)
    reg.counter("train.step").set(3)
    reg.histogram("train.step_s").observe(0.01)
    reg.emit(step=3)
    reg.emit(step=4)
    recs = [json.loads(l) for l in open(path)]
    assert len(recs) == 2
    assert recs[0]["step"] == 3 and recs[0]["metrics"]["train.step"] == 3
    assert recs[1]["metrics"]["train.step_s"]["count"] == 1


def test_prometheus_export():
    reg = MetricRegistry()
    reg.counter("serve.ticks").set(5)
    reg.gauge("serve.decode_time").set(1.5)
    h = reg.histogram("serve.ttft_s", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    text = reg.to_prometheus()
    assert "# TYPE serve_ticks counter\nserve_ticks 5" in text
    assert "serve_decode_time 1.5" in text
    # cumulative buckets: 1 under 0.1, 2 under 1.0, 3 total
    assert 'serve_ttft_s_bucket{le="0.1"} 1' in text
    assert 'serve_ttft_s_bucket{le="1"} 2' in text
    assert 'serve_ttft_s_bucket{le="+Inf"} 3' in text
    assert "serve_ttft_s_count 3" in text


# ---------------------------------------------------------------------------
# tracer + schema validation units
# ---------------------------------------------------------------------------

def test_tracer_spans_nest_and_validate(tmp_path):
    tr = Tracer()
    tr.name_thread(0, "serve")
    with tr.span("outer", tid=0, tick=1):
        with tr.span("inner", tid=0):
            pass
    with tr.span("offload", cat="offload", tid=1, seq=7):
        pass
    doc = tr.to_doc()
    assert validate_trace(doc) == []
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert [s["name"] for s in spans] == ["inner", "outer", "offload"]
    assert spans[1]["args"] == {"tick": 1}
    # spans carry the ids downstream tooling joins on
    assert spans[2]["args"]["seq"] == 7
    path = tr.export(str(tmp_path / "t.json"))
    assert validate_trace(json.load(open(path))) == []


def test_validate_trace_rejects_malformed():
    assert validate_trace({}) != []
    assert validate_trace({"traceEvents": []}) != []
    # missing required fields
    assert validate_trace({"traceEvents": [{"name": "x"}]}) != []
    # negative duration
    bad = {"traceEvents": [{"name": "x", "ph": "X", "pid": 1, "tid": 0,
                            "ts": 0.0, "dur": -1.0}]}
    assert any("dur" in p for p in validate_trace(bad))
    # overlapping (non-nested) spans on one lane
    overlap = {"traceEvents": [
        {"name": "a", "ph": "X", "pid": 1, "tid": 0, "ts": 0.0, "dur": 10.0},
        {"name": "b", "ph": "X", "pid": 1, "tid": 0, "ts": 5.0, "dur": 10.0},
    ]}
    assert any("overlaps" in p for p in validate_trace(overlap))
    # same shape on separate lanes is fine
    two_lanes = {"traceEvents": [
        {"name": "a", "ph": "X", "pid": 1, "tid": 0, "ts": 0.0, "dur": 10.0},
        {"name": "b", "ph": "X", "pid": 1, "tid": 1, "ts": 5.0, "dur": 10.0},
    ]}
    assert validate_trace(two_lanes) == []


# ---------------------------------------------------------------------------
# flight recorder units
# ---------------------------------------------------------------------------

def test_recorder_ring_bounded_and_postmortem(tmp_path):
    rec = FlightRecorder(capacity=4, out_dir=str(tmp_path))
    for i in range(10):
        rec.record("user", 1, "push", seq=i)
    rec.record("slot", 0, "admit", rid=3)
    assert rec.keys() == [("slot", 0), ("user", 1)]
    evs = rec.events("user", 1)
    assert len(evs) == 4 and [e["seq"] for e in evs] == [6, 7, 8, 9]
    pm = rec.dump("user", 1, "quarantined after 2 failed fit rounds")
    assert pm["reason"].startswith("quarantined")
    assert [e["seq"] for e in pm["events"]] == [6, 7, 8, 9]
    assert os.path.exists(pm["path"])
    on_disk = json.load(open(pm["path"]))
    assert on_disk["events"][-1]["seq"] == 9
    # dumping an unknown key is an empty postmortem, not a crash
    assert rec.dump("slot", 99, "no such ring")["events"] == []


def test_recorder_capacity_validated():
    with pytest.raises(ValueError):
        FlightRecorder(capacity=0)


# ---------------------------------------------------------------------------
# zero-cost-when-disabled: identity, not timing
# ---------------------------------------------------------------------------

def test_disabled_paths_share_null_singletons():
    tm_off = Telemetry(enabled=False)
    assert not tm_off and tm_off.tracer is None and tm_off.recorder is None
    assert tm_off.span("x") is NULL_CONTEXT
    assert tm_off.registry.counter("a") is NULL_METRIC
    assert tm_off.snapshot() == {}
    assert tm_off.export_trace("/nonexistent/never-written") is None
    tm_off.record("user", 0, "kind")
    assert tm_off.dump("user", 0, "r") is None
    # Telemetry(enabled=False) and telemetry=None are indistinguishable
    cfg = _tiny()
    eng_none = ServeEngine(cfg, M.init(cfg, jax.random.PRNGKey(0)), slots=2,
                           max_len=32)
    assert eng_none.tm is None
    assert eng_none._span("serve.tick") is NULL_CONTEXT
    assert eng_none._h_ttft is NULL_METRIC
    assert eng_none.telemetry_snapshot() == {}
    # enabled-without-trace still has no tracer: spans stay free
    tm_plain = Telemetry()
    assert tm_plain and tm_plain.span("x") is NULL_CONTEXT


def test_disabled_span_overhead_bounded():
    """100k disabled span entries must be pure-python cheap (no allocation,
    no syscalls) — an absolute wall bound, generous enough for shared CI."""
    tm_off = Telemetry(enabled=False)
    t0 = time.perf_counter()
    for _ in range(100_000):
        with tm_off.span("serve.tick"):
            pass
    assert time.perf_counter() - t0 < 2.0


# ---------------------------------------------------------------------------
# serve engine: bit-identity, counter consistency, tail latency
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(SERVE_CASES))
def test_tokens_bit_identical_telemetry_on_off(name, tmp_path):
    _, ref_outs = _serve(name, telemetry=None)
    tm = Telemetry(trace=True, out_dir=str(tmp_path))
    eng, outs = _serve(name, telemetry=tm)
    assert outs == ref_outs, "telemetry must never perturb generated tokens"
    # and the instrumented run actually observed the work
    snap = eng.telemetry_snapshot()
    assert snap["serve.completed"] == len(ref_outs)
    assert snap["serve.ttft_s"]["count"] == len(ref_outs)
    assert validate_trace(tm.tracer.to_doc()) == []


def test_counters_agree_across_engine_modes():
    """Token/request counters must agree between the batched baseline and the
    chunked+paged+burst engine on the same workload — tick/dispatch counters
    (ticks, prefill_calls, chunk_rounds) legitimately differ."""
    base_eng, base_outs = _serve("smollm-135m", prefill_chunk=None,
                                 kv_layout="dense",
                                 telemetry=Telemetry())
    burst_eng, burst_outs = _serve("smollm-135m", decode_burst=4,
                                   telemetry=Telemetry())
    assert base_outs == burst_outs
    a, b = base_eng.telemetry_snapshot(), burst_eng.telemetry_snapshot()
    for key in ("serve.tokens", "serve.decode_tokens", "serve.prefill_tokens",
                "serve.completed", "serve.admitted", "serve.rejected"):
        assert a[key] == b[key], f"{key}: {a[key]} != {b[key]}"
    # the registry mirrors the legacy dict exactly — same authority
    assert a["serve.tokens"] == base_eng.stats["tokens"]
    assert b["serve.decode_tokens"] == burst_eng.stats["decode_tokens"]
    # paged engine exposes pager.* next to serve.*
    assert b["pager.allocs"] == burst_eng.pager.stats["allocs"]
    burst_eng.pager.assert_empty()


def test_throughput_percentiles_always_on():
    """Tail percentiles in throughput() ride the always-on rings: present
    without telemetry, shaped {count, mean, max, p50, p95, p99}."""
    eng, outs = _serve("smollm-135m", telemetry=None)
    tp = eng.throughput()
    for key in ("ttft", "latency", "decode_tick", "prefill"):
        p = tp[key]
        assert p is not None and p["count"] > 0
        assert set(p) == {"count", "mean", "max", "p50", "p95", "p99"}
        assert p["p50"] <= p["p95"] <= p["p99"] <= p["max"]
    assert tp["ttft"]["count"] == len(outs)
    assert tp["mean_ttft"] == pytest.approx(tp["ttft"]["mean"])


def test_serve_trace_schema_and_summary(tmp_path):
    """Tier-1 trace schema acceptance: a chunked+paged run exports valid
    Chrome-trace JSON with the serve-span vocabulary, and the
    ``repro.trace_summary`` CLI parses both artifacts."""
    from repro import trace_summary

    tm = Telemetry(trace=True, out_dir=str(tmp_path))
    eng, _ = _serve("smollm-135m", telemetry=tm)
    doc = tm.tracer.to_doc()
    assert validate_trace(doc) == []
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert {"serve.tick", "serve.admit", "serve.prefill_chunk",
            "serve.decode"} <= names
    # every decode span records its live-slot count and burst width
    decodes = [e for e in doc["traceEvents"]
               if e.get("ph") == "X" and e["name"] == "serve.decode"]
    assert decodes and all(
        e["args"]["live"] >= 1 and e["args"]["burst"] >= 1 for e in decodes)
    # the lane is named for the viewer
    assert any(e["ph"] == "M" and e["args"]["name"] == "serve"
               for e in doc["traceEvents"])

    trace_path = tm.export_trace(str(tmp_path / "serve_trace.json"))
    snap_path = str(tmp_path / "serve_metrics.json")
    with open(snap_path, "w") as f:
        json.dump(eng.telemetry_snapshot(), f)
    assert trace_summary.main([trace_path, "--metrics", snap_path]) == 0
    table = trace_summary.span_table(json.load(open(trace_path)))
    assert any(row["name"] == "serve.tick" for row in table)


def test_flight_recorder_scopes_serve(tmp_path):
    tm = Telemetry(out_dir=str(tmp_path))
    eng, _ = _serve("smollm-135m", telemetry=tm)
    keys = tm.recorder.keys()
    # per-slot rings for the serve path, per-user rings for bank installs
    assert any(s == "slot" for s, _ in keys)
    slot_kinds = {e["kind"] for s, k in keys if s == "slot"
                  for e in tm.recorder.events(s, k)}
    assert {"admit", "first_token", "retire"} <= slot_kinds
    # a clean run dumps no postmortems
    assert tm.recorder.postmortems == []


# ---------------------------------------------------------------------------
# train loop: metrics.jsonl + telemetry.jsonl satellites
# ---------------------------------------------------------------------------

def test_trainloop_records_watchdog_and_channel_health(tmp_path):
    cfg = _tiny()
    key = jax.random.PRNGKey(0)
    params = M.init(cfg, key)
    cc = ColaConfig(mode="faithful_offload", family="lowrank", taps="qv",
                    rank=4, merged=True)
    tm = Telemetry(out_dir=str(tmp_path))
    sess = ColaSession(cfg, cc, params, key, optimizer=opt.sgd(0.05),
                       telemetry=tm)
    data = SyntheticLM(cfg, batch=2, seq=16, seed=3)
    loop = TrainLoop(sess, data, str(tmp_path / "run"), log_every=2,
                     telemetry=tm)
    out = loop.run(4, resume=False)

    recs = [json.loads(l)
            for l in open(str(tmp_path / "run" / "metrics.jsonl"))]
    assert recs, "metrics.jsonl must have records"
    for rec in recs:
        wd = rec["watchdog"]
        assert wd["steps"] >= 1 and "median_s" in wd and "p95_s" in wd
        ch = rec["channel_health"]["0"] if "0" in rec["channel_health"] \
            else rec["channel_health"][0]
        assert ch["version"] >= 0 and not ch["quarantined"]
        assert "last_error" in ch and "last_error_seq" in ch
    # run summary carries the watchdog tail stats
    assert out["watchdog"]["steps"] == 4
    assert out["watchdog"]["step_s"]["count"] == 4

    # the registry streamed one snapshot per log point with train.* and
    # channel.* namespaces
    t_recs = [json.loads(l)
              for l in open(str(tmp_path / "run" / "telemetry.jsonl"))]
    assert t_recs
    m = t_recs[-1]["metrics"]
    assert m["train.step"] == 3 and m["train.watchdog.steps"] == 4
    assert m["train.step_s"]["count"] == 4
    assert m["channel.u0.version"] == 4 and m["channel.u0.quarantined"] == 0
