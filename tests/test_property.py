"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import adapters as ad
from repro.core import merge
from repro.core.offload import dequant_int8, quant_int8
from repro.kernels import ref
from repro.optim import optimizers as opt
from repro.utils import flatten_dict, unflatten_dict

SET = dict(max_examples=25, deadline=None)


@given(d_in=st.integers(4, 64), d_out=st.integers(4, 64),
       rank=st.integers(1, 8), seed=st.integers(0, 2**30))
@settings(**SET)
def test_adapter_zero_init_property(d_in, d_out, rank, seed):
    """Paper Alg. 1: adapters initialise to g(x) == 0 for every family."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(jax.random.fold_in(key, 1), (3, d_in))
    for fam in ("lowrank", "linear", "mlp"):
        w = ad.init(fam, key, d_in, d_out, rank=rank, hidden=8)
        y = ad.apply(fam, w, x)
        assert y.shape == (3, d_out)
        np.testing.assert_allclose(np.asarray(y), 0.0, atol=1e-6)


@given(d=st.integers(4, 48), rank=st.integers(1, 8), seed=st.integers(0, 2**30),
       scale=st.floats(0.1, 2.0))
@settings(**SET)
def test_merge_matches_adapter_apply(d, rank, seed, scale):
    """Prop 2: merged weights reproduce base(x) + scale*g(x) exactly."""
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (d, d))
    for fam in ("lowrank", "linear"):
        aw = ad.init(fam, jax.random.fold_in(key, 1), d, d, rank=rank)
        aw = jax.tree.map(lambda a: a + 0.1 * jax.random.normal(
            jax.random.fold_in(key, 2), a.shape), aw)
        delta = ad.merge_delta(fam, aw, scale)
        x = jax.random.normal(jax.random.fold_in(key, 3), (5, d))
        y1 = x @ (w + delta)
        y2 = x @ w + scale * ad.apply(fam, aw, x)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-4, atol=1e-4)


@given(rows=st.integers(1, 32), cols=st.integers(1, 64),
       seed=st.integers(0, 2**30), scale=st.floats(0.01, 100.0))
@settings(**SET)
def test_int8_quantisation_bounded_error(rows, cols, seed, scale):
    """Offload compression: per-row error bounded by scale/127 elementwise."""
    x = np.random.default_rng(seed).standard_normal((rows, cols)) * scale
    q, s = quant_int8(jnp.asarray(x, jnp.float32))
    back = np.asarray(dequant_int8(q, s))
    bound = np.asarray(s) * 0.5 + 1e-9
    assert np.all(np.abs(back - x) <= bound + 1e-6)


@given(seed=st.integers(0, 2**30), steps=st.integers(1, 5),
       lr=st.floats(1e-4, 1e-1))
@settings(**SET)
def test_adamw_decreases_quadratic(seed, steps, lr):
    key = jax.random.PRNGKey(seed)
    target = jax.random.normal(key, (8,))
    params = {"w": jnp.zeros(8)}
    o = opt.adamw(lr)
    state = o.init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    l0 = float(loss(params))
    for _ in range(steps):
        g = jax.grad(loss)(params)
        upd, state = o.update(g, state, params)
        params = opt.apply_updates(params, upd)
    assert float(loss(params)) <= l0 + 1e-9


@given(seed=st.integers(0, 2**30))
@settings(**SET)
def test_flatten_roundtrip(seed):
    rng = np.random.default_rng(seed)
    tree = {"a": {"b": rng.standard_normal(3), "c": {"d": rng.standard_normal(2)}},
            "e": rng.standard_normal(1)}
    flat = flatten_dict(tree)
    back = unflatten_dict(flat)
    assert jax.tree.structure(tree) == jax.tree.structure(back)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(x, y)


@given(sq=st.sampled_from([16, 32, 64]), sk=st.sampled_from([16, 32, 64]),
       h=st.integers(1, 4), seed=st.integers(0, 2**30),
       window=st.sampled_from([0, 8, 1 << 30]))
@settings(**SET)
def test_sdpa_rows_are_convex_combinations(sq, sk, h, seed, window):
    """softmax(QK^T)V rows lie inside the convex hull of V rows: outputs are
    bounded by [min(V), max(V)] per head-dim."""
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (1, sq, h, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, sk, h, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, sk, h, 16))
    qp = jnp.arange(sq)[None] + sk  # every query sees at least one key
    kp = jnp.arange(sk)[None]
    o = ref.sdpa(q, k, v, q_positions=qp, kv_positions=kp,
                 window=window or None)
    vmax = jnp.max(v, axis=1, keepdims=True)
    vmin = jnp.min(v, axis=1, keepdims=True)
    if window and window < 1 << 30:
        return  # some rows may see only part of V; hull bound still holds
    assert bool(jnp.all(o <= vmax + 1e-4)) and bool(jnp.all(o >= vmin - 1e-4))


@given(seed=st.integers(0, 2**30), t=st.sampled_from([16, 32]),
       u=st.integers(1, 4))
@settings(**SET)
def test_multi_lora_matches_per_user_apply(seed, t, u):
    key = jax.random.PRNGKey(seed)
    d, r = 16, 4
    x = jax.random.normal(key, (t, d))
    A = jax.random.normal(jax.random.fold_in(key, 1), (u, d, r))
    B = jax.random.normal(jax.random.fold_in(key, 2), (u, r, d))
    idx = jax.random.randint(jax.random.fold_in(key, 3), (t,), 0, u)
    y = ref.multi_lora(x, A, B, idx)
    for i in range(t):
        ui = int(idx[i])
        expect = (x[i] @ A[ui]) @ B[ui]
        np.testing.assert_allclose(np.asarray(y[i]), np.asarray(expect),
                                   rtol=1e-4, atol=1e-4)
