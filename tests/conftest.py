import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def make_batch(cfg, B, S, key, with_users=0):
    kt, kl, ke, ku = jax.random.split(key, 4)
    cb = (cfg.n_codebooks,) if cfg.n_codebooks else ()
    if cfg.embed_input:
        batch = {"embeds": jax.random.normal(ke, (B, S, cfg.d_model), jnp.float32),
                 "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab_size)}
    else:
        batch = {"tokens": jax.random.randint(kt, (B, S) + cb, 0, cfg.vocab_size),
                 "labels": jax.random.randint(kl, (B, S) + cb, 0, cfg.vocab_size)}
    if with_users:
        batch["user_id"] = jax.random.randint(ku, (B,), 0, with_users)
    return batch
