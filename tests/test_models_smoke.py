"""Per-architecture smoke tests (assignment requirement): a REDUCED config of
the same family runs one forward + one train step on CPU, asserting output
shapes and no NaNs. Also prefill/decode consistency per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ColaConfig
from repro.core import gl
from repro.models import model as M
from tests.conftest import make_batch

ALL_ARCHS = list(registry.ASSIGNED) + ["gpt2-small"]


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = registry.reduced_config(arch)
    key = jax.random.PRNGKey(0)
    params = M.init(cfg, key)
    B, S = 2, 32
    batch = make_batch(cfg, B, S, key)
    logits, _ = M.forward(cfg, params, batch)
    if cfg.n_codebooks:
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))

    # one ColA train step (Mode B) — loss finite, adapter grads finite
    cc = ColaConfig(mode="fused_fit", family="lowrank", taps="qv", rank=4)
    spec = gl.make_spec(cfg, cc)
    adapters = gl.init_adapters(cfg, cc, key)
    loss, grads, _ = gl.train_step_b(cfg, spec, params, adapters, batch)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf)))


@pytest.mark.parametrize("arch", ["smollm-135m", "gemma2-9b", "mamba2-370m",
                                  "zamba2-7b", "musicgen-medium"])
def test_prefill_decode_matches_forward(arch):
    cfg = registry.reduced_config(arch)
    key = jax.random.PRNGKey(1)
    params = M.init(cfg, key)
    B, S = 2, 16
    cb = (cfg.n_codebooks,) if cfg.n_codebooks else ()
    toks = jax.random.randint(key, (B, S + 1) + cb, 0, cfg.vocab_size)
    logits_full, _ = M.forward(cfg, params, {"tokens": toks})
    logits_pre, cache = M.prefill(cfg, params, {"tokens": toks[:, :S]})
    np.testing.assert_allclose(np.asarray(logits_pre[:, 0]),
                               np.asarray(logits_full[:, S - 1]),
                               rtol=1e-4, atol=1e-4)
    # graft prefill cache into a longer decode cache
    cache2 = M.init_cache(cfg, B, S + 8)
    cache2 = jax.tree.map(
        lambda d, s: d.at[tuple(slice(0, x) for x in s.shape)].set(
            s.astype(d.dtype)) if d.shape != s.shape else s.astype(d.dtype),
        cache2, cache)
    step = {"tokens": toks[:, S:S + 1],
            "positions": jnp.full((B,), S, jnp.int32)}
    logits_dec, cache3 = M.decode_step(cfg, params, step, cache2)
    np.testing.assert_allclose(np.asarray(logits_dec[:, 0]),
                               np.asarray(logits_full[:, S]),
                               rtol=1e-4, atol=2e-4)
    assert jax.tree.structure(cache3) == jax.tree.structure(cache2)


def test_moe_dispatch_impls_agree():
    cfg = registry.reduced_config("qwen3-moe-30b-a3b").replace(
        capacity_factor=8.0)
    key = jax.random.PRNGKey(2)
    params = M.init(cfg, key)
    batch = make_batch(cfg, 2, 32, key)
    le, _ = M.forward(cfg.replace(moe_impl="einsum"), params, batch)
    ls, _ = M.forward(cfg.replace(moe_impl="sort"), params, batch)
    ld, _ = M.forward(cfg.replace(moe_impl="dense"), params, batch)
    np.testing.assert_allclose(np.asarray(le), np.asarray(ls), atol=2e-5)
    np.testing.assert_allclose(np.asarray(le), np.asarray(ld), atol=2e-5)


def test_gemma2_flavors_change_output():
    """softcap / post-norm / local-global actually do something."""
    cfg = registry.reduced_config("gemma2-9b")
    key = jax.random.PRNGKey(3)
    params = M.init(cfg, key)
    batch = make_batch(cfg, 1, 32, key)
    base, _ = M.forward(cfg, params, batch)
    nocap, _ = M.forward(cfg.replace(attn_softcap=0.0, final_softcap=0.0),
                         params, batch)
    assert not np.allclose(np.asarray(base), np.asarray(nocap))


def test_full_configs_match_assignment():
    """Pin the exact assigned architecture hyperparameters."""
    c = registry.get_config("mistral-large-123b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (88, 12288, 96, 8, 28672, 32768)
    c = registry.get_config("qwen3-moe-30b-a3b")
    assert (c.n_experts, c.moe_top_k, c.d_expert, c.vocab_size) == \
        (128, 8, 768, 151936)
    c = registry.get_config("gemma2-9b")
    assert (c.n_layers, c.d_model, c.vocab_size, c.attn_pattern) == \
        (42, 3584, 256000, "local_global")
    c = registry.get_config("mamba2-370m")
    assert (c.n_layers, c.d_model, c.ssm_state, c.vocab_size) == \
        (48, 1024, 128, 50280)
    c = registry.get_config("zamba2-7b")
    assert (c.n_layers, c.d_model, c.shared_attn_every, c.ssm_state) == \
        (81, 3584, 6, 64)
    c = registry.get_config("dbrx-132b")
    assert (c.n_experts, c.moe_top_k, c.d_expert) == (16, 4, 10752)
    c = registry.get_config("musicgen-medium")
    assert (c.n_codebooks, c.vocab_size, c.n_heads) == (4, 2048, 24)
    c = registry.get_config("pixtral-12b")
    assert c.embed_input and c.d_model == 5120
    # 40 assigned cells with documented long_500k skips
    assert len(registry.ASSIGNED) == 10
    cells = registry.all_cells()
    skips = registry.skipped_cells()
    assert len(cells) + len(skips) == 40
    assert all(s == "long_500k" for _, s, _ in skips)
    assert {a for a, _, _ in skips} == set(registry.ASSIGNED) - {
        "mamba2-370m", "zamba2-7b"}
