"""Chaos suite for the fault-tolerant FTaaS offload channel.

Acceptance invariants (ISSUE 6):
- under every single-fault profile (drop / delay / corrupt / duplicate /
  NaN-poison), K-user training finishes every round and stays within
  tolerance of the fault-free run;
- recoverable faults (retry / dedup / late delivery) reproduce the fault-free
  run *bit-for-bit*;
- a persistently poisoned user is quarantined and rolled back to the
  last-good bank version, and no healthy user's adapters are ever perturbed
  by the poisoned peer (version-rollback invariant, bit-for-bit);
- the serve engine never installs an unvalidated adapter bank — degraded
  users keep serving their last-good adapters.

Channel mechanics (dedup, checksums, backoff, dead letters, fit timeout,
update-norm guard) are unit-tested against a stub offloader so they run in
milliseconds.
"""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ColaConfig
from repro.core import gl
from repro.core.channel import OffloadChannel
from repro.core.collab import CollabSession
from repro.core.session import ColaSession
from repro.data.pipeline import SyntheticLM
from repro.models import model as M
from repro.optim import optimizers as opt
from repro.runtime.faults import (SINGLE_FAULTS, FaultInjector, FaultProfile,
                                  RetryPolicy)
from repro.runtime.serve_loop import Request, ServeEngine, publish_banks
from repro.runtime.train_loop import TrainLoop

STEPS = 8

# injector seed for the chaos matrix — CI sweeps this (fixed seed matrix);
# every assertion below is seed-robust (bit-exactness is only claimed for
# rounds that recovered, via the rollbacks == 0 guard)
SEED = int(os.environ.get("CHAOS_SEED", "0"))

# virtual-time policy: no wall-clock sleeps, bounded retries
POLICY = RetryPolicy(max_attempts=6, timeout_ticks=2, backoff_base=0.0,
                     sleep=lambda s: None)


def _mk():
    cfg = registry.reduced_config("smollm-135m").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=128)
    key = jax.random.PRNGKey(0)
    return cfg, M.init(cfg, key), key


def _run_collab(injector=None, steps=STEPS, all_rows_user0=False,
                quarantine_after=2, telemetry=None):
    cfg, params, key = _mk()
    cc = ColaConfig(mode="faithful_offload", family="lowrank", taps="qv",
                    rank=4, merged=True, users=2)
    collab = CollabSession(cfg, cc, params, key, optimizer=opt.sgd(0.1),
                           injector=injector, policy=POLICY,
                           quarantine_after=quarantine_after,
                           telemetry=telemetry)
    data = SyntheticLM(cfg, batch=4, seq=16, seed=2, users=2)
    losses = []
    for t in range(steps):
        b = data.batch_at(t)
        uid = (np.zeros(4, np.int32) if all_rows_user0 else b["user_id"])
        losses.append(collab.train_step(
            {k: jnp.asarray(v) for k, v in b.items() if k != "user_id"},
            jnp.asarray(uid)))
    return collab, losses


def _banks(collab):
    return [jax.tree.map(np.asarray, ch.adapters) for ch in collab.channels]


def _bit_equal(a, b) -> bool:
    return all(np.array_equal(x, y)
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.fixture(scope="module")
def ref_mixed():
    """Fault-free K=2 reference run with mixed user rows."""
    collab, losses = _run_collab()
    return _banks(collab), losses


@pytest.fixture(scope="module")
def ref_user0_only():
    """Fault-free reference where every row belongs to user 0 (user 1's bank
    stays at its g(x)=0 init, contributing zero delta to the merged model)."""
    collab, losses = _run_collab(all_rows_user0=True)
    return _banks(collab), losses


# ---------------------------------------------------------------------------
# the single-fault chaos matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fault", sorted(SINGLE_FAULTS))
def test_single_fault_training_survives(fault, ref_mixed):
    """Under each fault profile on user 1's channel, every round completes,
    user 0's channel stays pristine, and training matches the fault-free run
    within tolerance (exactly, when every fault was recovered)."""
    ref_banks, ref_losses = ref_mixed
    injector = FaultInjector({1: SINGLE_FAULTS[fault]}, seed=SEED)
    collab, losses = _run_collab(injector=injector)

    assert len(losses) == STEPS and np.all(np.isfinite(losses))
    np.testing.assert_allclose(losses, ref_losses, atol=0.02)

    h0, h1 = collab.channels[0].health(), collab.channels[1].health()
    # only the faulted user may degrade — never quarantine the healthy one
    assert not h0["quarantined"]
    assert h0["send_retries"] == 0 and h0["rollbacks"] == 0
    assert h0["version"] == STEPS
    # round accounting: with interval 1, every round either commits (version
    # bump), rolls back, or is refused while quarantined — none may vanish
    assert (h1["version"] + h1["rollbacks"] + h1["refused_quarantined"]
            == STEPS)
    if h1["rollbacks"] == 0:
        # every fault recovered (resend / dedup / late delivery): the run is
        # indistinguishable from the fault-free one, bit for bit
        for got, want in zip(_banks(collab), ref_banks):
            assert _bit_equal(got, want), f"{fault}: bank diverged"


def test_drop_delay_duplicate_are_fully_recoverable(ref_mixed):
    """The retry/dedup/late-delivery paths are lossless at these rates: final
    banks equal the fault-free run bit-for-bit and retries actually happened
    (the test would be vacuous if no fault fired). Pinned to injector seed 0
    — the seed verified to recover every fault within the retry budget."""
    ref_banks, _ = ref_mixed
    for fault in ("drop", "delay", "duplicate"):
        injector = FaultInjector({1: SINGLE_FAULTS[fault]}, seed=0)
        collab, _ = _run_collab(injector=injector)
        assert sum(injector.injected.values()) > 0, f"{fault}: nothing injected"
        assert collab.channels[1].health()["rollbacks"] == 0
        for got, want in zip(_banks(collab), ref_banks):
            assert _bit_equal(got, want), f"{fault}: bank diverged"


# ---------------------------------------------------------------------------
# version-rollback invariant: poisoned peer never perturbs a healthy user
# ---------------------------------------------------------------------------

def test_poisoned_peer_quarantined_healthy_user_bit_exact(ref_user0_only):
    """User 1's every adapter return is NaN-poisoned: validation must reject
    each one, roll user 1 back to the last-good (init) version, quarantine
    them — and user 0's training must be bit-for-bit identical to the
    fault-free run."""
    ref_banks, ref_losses = ref_user0_only
    injector = FaultInjector(
        {1: FaultProfile(nan=1.0, targets=("adapters",))}, seed=SEED)
    collab, losses = _run_collab(injector=injector, all_rows_user0=True)

    ch0, ch1 = collab.channels
    # quarantine exactly the poisoned user, frozen at version 0 (init)
    assert ch1.quarantined and not ch0.quarantined
    assert ch1.version == 0 and ch0.version == STEPS
    assert ch1.health()["fit_rejected"] > 0 and ch1.health()["rollbacks"] >= 2
    assert len(ch1.dead_letters) >= 2
    # healthy user: bit-for-bit unperturbed; poisoned user: rolled back to init
    assert _bit_equal(_banks(collab)[0], ref_banks[0])
    assert _bit_equal(_banks(collab)[1], ref_banks[1])
    # the merged server pass never saw a poisoned bank
    np.testing.assert_array_equal(losses, ref_losses)
    # quarantined user's later payloads are refused, not buffered
    assert ch1.health()["refused_quarantined"] > 0
    assert not ch1.offloader.buffers

    # publish into a serve engine: only validated version bumps install
    cfg, params, _ = _mk()
    init_banks = [jax.tree.map(np.asarray, ch.offloader.adapters)
                  for ch in collab.channels]
    eng = ServeEngine(cfg, params, slots=2, max_len=32,
                      user_adapters=init_banks)
    before = jax.tree.map(np.asarray, eng.bank)
    assert publish_banks(eng, collab.channels) == 1
    assert eng.bank_versions.tolist() == [STEPS, 0]
    # user 1's slice of the bank is untouched (serving last-good)
    for tap in eng.bank:
        for name in ("A", "B"):
            got = np.asarray(eng.bank[tap][name])
            want = np.asarray(before[tap][name])
            sl = ((slice(None), 1) if got.ndim == 4 else (1,))
            np.testing.assert_array_equal(got[sl], want[sl])


def test_quarantine_postmortem_names_failing_seq(tmp_path):
    """ISSUE 10 acceptance: a chaos quarantine run must freeze a flight-
    recorder postmortem for the poisoned user whose event ring names the
    failing channel seq ids — injected fault, rejection, rollback and the
    final quarantine, explainable without re-running the chaos."""
    import json

    from repro.telemetry import Telemetry

    tm = Telemetry(out_dir=str(tmp_path))
    injector = FaultInjector(
        {1: FaultProfile(nan=1.0, targets=("adapters",))}, seed=SEED,
        telemetry=tm)
    collab, _ = _run_collab(injector=injector, all_rows_user0=True,
                            telemetry=tm)
    ch1 = collab.channels[1]
    assert ch1.quarantined
    # health names the terminal failure + the offending seq id
    h = ch1.health()
    assert h["last_error"] == "quarantined" or "adapter" in h["last_error"] \
        or "finite" in h["last_error"]
    assert isinstance(h["last_error_seq"], int)

    pms = [p for p in tm.recorder.postmortems
           if p["scope"] == "user" and p["key"] == 1]
    assert pms, "quarantine run must dump user-1 postmortems"
    q = [p for p in pms if p["reason"].startswith("quarantined after")]
    assert len(q) == 1, "exactly one quarantine postmortem for the user"
    pm = q[0]
    kinds = [e["kind"] for e in pm["events"]]
    # the injected cause sits in the same ring as the channel's reaction
    assert "fault_injected" in kinds
    assert "rollback" in kinds and "quarantine" in kinds
    # rejection/rollback breadcrumbs carry the failing seq id
    failing = [e["seq"] for e in pm["events"]
               if e["kind"] in ("fit_rejected", "rollback") and "seq" in e]
    assert failing and all(isinstance(s, int) for s in failing)
    assert h["last_error_seq"] in failing
    # the on-disk postmortem round-trips with the in-memory record
    assert pm["path"] and os.path.exists(pm["path"])
    with open(pm["path"]) as f:
        on_disk = json.load(f)
    assert on_disk["reason"] == pm["reason"]
    assert [e["kind"] for e in on_disk["events"]] == kinds
    # the healthy user never quarantines, so never dumps
    assert not any(p["key"] == 0 for p in tm.recorder.postmortems
                   if p["scope"] == "user")


# ---------------------------------------------------------------------------
# channel mechanics against a stub offloader (no model, milliseconds)
# ---------------------------------------------------------------------------

class StubOffloader:
    """Duck-typed Offloader: fit adds +1 to the single weight."""

    def __init__(self, fit_s: float = 0.0, fit_delta: float = 1.0):
        self.adapters = {"w": np.zeros(3, np.float32)}
        self.opt_state = {}
        self.buffers: dict[str, list] = {}
        self._pushes = 0
        self.interval = 1
        self.fit_s = fit_s
        self.fit_delta = fit_delta
        self.fits = 0

    @property
    def ready(self):
        return self._pushes > 0 and bool(self.buffers)

    def push(self, data):
        self.buffers.setdefault("t", []).append(data)
        self._pushes += 1

    def maybe_fit(self):
        if not self.ready:
            return None
        if self.fit_s:
            time.sleep(self.fit_s)
        self.adapters = {"w": self.adapters["w"] + self.fit_delta}
        self.buffers.clear()
        self.fits += 1
        return self.adapters


def _payload(v=1.0):
    return {"t": (np.full(4, v, np.float32), np.full(4, 2 * v, np.float32))}


def _channel(profile=None, seed=0, **kw):
    injector = (FaultInjector({0: profile}, seed=seed)
                if profile is not None else None)
    return OffloadChannel(StubOffloader(), injector=injector,
                          policy=kw.pop("policy", POLICY), **kw)


def test_duplicates_are_deduped():
    ch = _channel(FaultProfile(duplicate=1.0))
    for i in range(5):
        assert ch.push(_payload(i + 1))
    assert ch.offloader._pushes == 5          # exactly-once delivery
    assert ch.health()["dup_discarded"] == 5


def test_corrupt_payload_is_never_buffered():
    ch = _channel(FaultProfile(corrupt=1.0))
    assert not ch.push(_payload())            # every copy corrupt -> dead letter
    h = ch.health()
    assert ch.offloader._pushes == 0
    assert h["corrupt_rejected"] == POLICY.max_attempts
    assert h["dead_letter_count"] == 1
    assert ch.dead_letters[0].kind == "payload"


def test_nan_payload_rejected_at_source_too():
    """A NaN gradient produced by the *server* (diverged user) is caught by
    payload validation instead of poisoning the offload buffers."""
    ch = _channel(None)
    bad = {"t": (np.full(4, np.nan, np.float32), np.ones(4, np.float32))}
    assert not ch.push(bad)
    assert ch.offloader._pushes == 0
    assert ch.health()["nan_rejected"] == POLICY.max_attempts


def test_delay_within_window_is_late_but_delivered():
    ch = _channel(FaultProfile(delay=1.0, delay_ticks=2))   # == timeout_ticks
    assert ch.push(_payload())
    h = ch.health()
    assert h["late_deliveries"] == 1 and h["late_dropped"] == 0


def test_delay_beyond_window_times_out():
    ch = _channel(FaultProfile(delay=1.0, delay_ticks=10))  # > timeout_ticks
    assert not ch.push(_payload())
    h = ch.health()
    assert h["late_dropped"] == POLICY.max_attempts
    assert h["dead_letter_count"] == 1


def test_fit_timeout_rolls_back_and_quarantines():
    off = StubOffloader(fit_s=0.25)
    policy = RetryPolicy(max_attempts=2, timeout_s=0.02, backoff_base=0.0,
                         sleep=lambda s: None)
    ch = OffloadChannel(off, policy=policy, quarantine_after=1)
    ch.push(_payload())
    assert ch.fit_round() is None
    h = ch.health()
    assert h["fit_timeouts"] == 2 and h["rollbacks"] == 1
    assert ch.quarantined and ch.version == 0
    # a timed-out fit keeps running on its abandoned worker thread (threads
    # cannot be killed) and may still mutate the offloader — wait for the
    # zombies to land, then check that reset() (the recovery hook) fences
    # them off by re-asserting the last-good bank
    time.sleep(0.6)
    ch.reset()
    assert not ch.quarantined and not ch.offloader.buffers
    np.testing.assert_array_equal(ch.adapters["w"], np.zeros(3, np.float32))


def test_update_norm_guard_rejects_exploding_bank():
    off = StubOffloader(fit_delta=1e9)
    ch = OffloadChannel(off, policy=POLICY, max_update_norm=1e3,
                        quarantine_after=1)
    ch.push(_payload())
    assert ch.fit_round() is None
    h = ch.health()
    assert h["fit_rejected"] == POLICY.max_attempts and h["rollbacks"] == 1
    np.testing.assert_array_equal(ch.adapters["w"], np.zeros(3, np.float32))
    assert "update norm" in ch.dead_letters[-1].reason


def test_commit_bumps_version_and_snapshots_last_good():
    ch = _channel(None)
    for i in range(3):
        ch.push(_payload(i + 1))
        assert ch.fit_round() is not None
    assert ch.version == 3
    np.testing.assert_array_equal(ch.last_good["w"], np.full(3, 3, np.float32))


def test_backoff_schedule_and_accounting():
    policy = RetryPolicy(max_attempts=4, backoff_base=1.0, backoff_mult=2.0,
                         backoff_max=100.0, jitter=0.0, sleep=lambda s: None)
    rng = np.random.default_rng(0)
    assert [policy.backoff(a, rng) for a in (1, 2, 3)] == [1.0, 2.0, 4.0]
    ch = OffloadChannel(StubOffloader(),
                        injector=FaultInjector({0: FaultProfile(drop=1.0)}),
                        policy=policy)
    assert not ch.push(_payload())
    assert ch.health()["backoff_s"] == pytest.approx(1.0 + 2.0 + 4.0 + 8.0)


def test_injector_is_deterministic_per_user():
    a = FaultInjector({1: FaultProfile(drop=0.5, corrupt=0.3)}, seed=7)
    b = FaultInjector({1: FaultProfile(drop=0.5, corrupt=0.3)}, seed=7)
    obj = _payload()
    outcomes = lambda inj: [len(inj.transmit(1, "payload", obj))
                            for _ in range(50)]
    assert outcomes(a) == outcomes(b)
    assert a.injected == b.injected
    # healthy users draw from their own stream: untouched by user 1's faults
    assert len(a.transmit(0, "payload", obj)) == 1
    assert a.injected == b.injected


# ---------------------------------------------------------------------------
# serve engine: unvalidated banks are never installed
# ---------------------------------------------------------------------------

def test_engine_never_serves_unvalidated_bank():
    cfg, params, key = _mk()
    cc = ColaConfig(mode="lora", family="lowrank", taps="qv", rank=4)
    ad0 = gl.init_adapters(cfg, cc, jax.random.fold_in(key, 1))
    ad1 = gl.init_adapters(cfg, cc, jax.random.fold_in(key, 2))
    eng = ServeEngine(cfg, params, slots=2, max_len=32,
                      user_adapters=[ad0, ad1])
    prompt = np.arange(6) % cfg.vocab_size

    def gen(engine, user):
        r = Request(rid=0, user=user, prompt=prompt, max_new=4)
        engine.submit(r)
        engine.run_until_idle()
        return r.out

    out_before = gen(eng, 1)
    # NaN-poisoned bank: rejected, serving unchanged
    bad = jax.tree.map(lambda a: a * np.nan, ad1)
    assert not eng.install_adapters(1, bad, version=1)
    # stale/replayed version: rejected even though values are fine
    assert not eng.install_adapters(1, ad1, version=0)
    # unknown user / wrong tap set: rejected
    assert not eng.install_adapters(7, ad1, version=1)
    assert not eng.install_adapters(1, {"nope": {}}, version=1)
    assert eng.stats["bank_installs"] == 0 and eng.stats["bank_rejected"] == 4
    assert gen(eng, 1) == out_before

    # a validated version bump installs and matches a fresh engine built with
    # the new bank; the other user's adapters are untouched
    ad1_new = jax.tree.map(
        lambda a: (a + 0.5 * jax.random.normal(jax.random.fold_in(key, 3),
                                               a.shape).astype(a.dtype)), ad1)
    out_u0_before = gen(eng, 0)
    assert eng.install_adapters(1, ad1_new, version=1)
    ref = ServeEngine(cfg, params, slots=2, max_len=32,
                      user_adapters=[ad0, ad1_new])
    assert gen(eng, 1) == gen(ref, 1)
    assert gen(eng, 0) == out_u0_before


# ---------------------------------------------------------------------------
# paged KV pool under churn: retirement never leaks or double-frees blocks
# ---------------------------------------------------------------------------

def test_paged_pool_churn_never_leaks_blocks():
    """Seeded request churn against a deliberately tight block pool — waves of
    mixed-length prompts (forcing reservation failures and FIFO queue waits),
    invalid submissions rejected mid-flight, and poisoned bank installs
    refused mid-flight. After the drain the pool must be whole: every alloc
    matched by a free, no block still owned, refcounts all zero."""
    rng = np.random.default_rng(SEED)
    cfg, params, key = _mk()
    cc = ColaConfig(mode="lora", family="lowrank", taps="qv", rank=4)
    banks = [gl.init_adapters(cfg, cc, jax.random.fold_in(key, u))
             for u in range(2)]
    # 12 blocks x 8 positions: three worst-case requests oversubscribe it
    eng = ServeEngine(cfg, params, slots=3, max_len=64, prefill_chunk=4,
                      kv_layout="paged", kv_block=8, kv_blocks=12,
                      user_adapters=banks)
    poisoned = jax.tree.map(lambda a: a * np.nan, banks[1])
    reqs, rid = [], 0
    for wave in range(6):
        for _ in range(int(rng.integers(1, 4))):
            p = rng.integers(0, cfg.vocab_size, size=int(rng.integers(1, 31)))
            r = Request(rid=rid, user=int(rng.integers(0, 2)), prompt=p,
                        max_new=int(rng.integers(1, 11)))
            rid += 1
            reqs.append(r)
            eng.submit(r)
        # mid-churn faults: an invalid request and a poisoned bank, both
        # rejected without touching any slot's pool accounting
        eng.submit(Request(rid=10_000 + wave, user=0,
                           prompt=np.array([], np.int32), max_new=1))
        assert not eng.install_adapters(1, poisoned, version=wave + 1)
        for _ in range(int(rng.integers(1, 6))):
            eng.tick()
    eng.run_until_idle()
    assert all(r.status == "done" and len(r.out) == r.max_new for r in reqs)
    eng.pager.assert_empty()
    assert eng.stats["kv_allocs"] == eng.stats["kv_frees"] > 0
    assert eng.stats["kv_blocks_in_use"] == 0
    assert eng.stats["kv_blocks_peak"] <= 12
    assert eng.stats["rejected"] == 6 and eng.stats["bank_rejected"] == 6


# ---------------------------------------------------------------------------
# watchdog recovery hook: straggler/hang -> checkpoint + channel reset
# ---------------------------------------------------------------------------

def test_straggler_recovery_checkpoints_and_resets_channels(tmp_path):
    cfg, params, key = _mk()
    cc = ColaConfig(mode="faithful_offload", family="lowrank", taps="qv",
                    rank=4)
    sess = ColaSession(cfg, cc, params, key, optimizer=opt.sgd(0.1))
    data = SyntheticLM(cfg, batch=4, seq=16, seed=3)
    loop = TrainLoop(sess, data, str(tmp_path), ckpt_every=100,
                     recover_on_straggler=True)
    loop.run(2, resume=False)
    # simulate a hung offload round: quarantined channel + stale buffers
    sess.channel.quarantined = True
    sess.offloader.buffers["junk"] = [object()]
    loop._on_straggler(2, dt=9.9, med=0.1)
    assert loop.recoveries == 1
    assert not sess.channel.quarantined
    assert not sess.offloader.buffers
    loop.ckpt.wait()
    assert loop.ckpt.latest_step() is not None
    summary = loop.run(3, resume=False)
    assert "channel_health" in summary and 0 in summary["channel_health"]
    assert summary["heartbeat_failures"] == 0
