"""The paper's core claims, in code.

- Prop 1: the gradient of the quadratic fit loss (Eq. 6) at w_t equals the true
  task-loss gradient — Mode A (faithful offload) == Mode B (fused fit) == LoRA.
- Merged-mode server pass (Alg. 1 l.3/8) gives the same adaptation gradients.
- ColA(Linear) == full-FT gradients on tapped weights (Prop 2 / §C.3).
- The fit loss itself is minimised in the gradient direction.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ColaConfig
from repro.core import gl, merge
from repro.models import model as M
from tests.conftest import make_batch

ARCHS_FOR_EQ = ["smollm-135m", "mamba2-370m", "zamba2-7b", "qwen3-moe-30b-a3b"]


def _setup(arch, family="lowrank", rank=4, scale=1.0, taps="qv"):
    cfg = registry.reduced_config(arch)
    if cfg.n_experts:
        cfg = cfg.replace(capacity_factor=8.0)  # dropless: keeps grads smooth
    key = jax.random.PRNGKey(1)
    params = M.init(cfg, key)
    cc = ColaConfig(mode="faithful_offload", family=family, taps=taps,
                    rank=rank, scale=scale)
    adapters = gl.init_adapters(cfg, cc, key)
    # non-zero adapters so dA is informative
    adapters = jax.tree.map(
        lambda a: a + 0.02 * jax.random.normal(jax.random.PRNGKey(7), a.shape),
        adapters)
    batch = make_batch(cfg, 2, 16, jax.random.fold_in(key, 3))
    return cfg, cc, params, adapters, batch


@pytest.mark.parametrize("arch", ARCHS_FOR_EQ)
def test_prop1_mode_a_equals_mode_b(arch):
    cfg, cc, params, adapters, batch = _setup(arch)
    spec_a = gl.make_spec(cfg, cc)
    spec_b = gl.make_spec(cfg, cc.__class__(mode="fused_fit", family=cc.family,
                                            taps=cc.taps, rank=cc.rank))
    loss_a, data, _ = gl.server_step_a(cfg, spec_a, params, adapters, batch)
    ga = gl.fit_grads(spec_a, adapters, data)
    loss_b, gb, _ = gl.train_step_b(cfg, spec_b, params, adapters, batch)
    assert np.allclose(float(loss_a), float(loss_b), rtol=1e-6)
    for tap in gb:
        for leaf in gb[tap]:
            a, b = np.asarray(ga[tap][leaf]), np.asarray(gb[tap][leaf])
            np.testing.assert_allclose(a, b, rtol=2e-4, atol=1e-6,
                                       err_msg=f"{arch} {tap}.{leaf}")


@pytest.mark.parametrize("scale", [1.0, 0.5])
def test_prop1_merged_server_pass(scale):
    cfg, cc, params, adapters, batch = _setup("smollm-135m", scale=scale)
    cc_m = ColaConfig(mode="faithful_offload", family="lowrank", taps="qv",
                      rank=4, scale=scale, merged=True)
    spec_m = gl.make_spec(cfg, cc_m)
    fams = dict(gl.make_spec(cfg, cc).families)
    pm = merge.merged_params(cfg, params, fams, adapters, scale)
    _, data_m, _ = gl.server_step_a(cfg, spec_m, pm, {}, batch)
    spec_fit = gl.make_spec(cfg, cc)
    gm = gl.fit_grads(spec_fit, adapters, data_m)
    spec_b = gl.make_spec(cfg, ColaConfig(mode="fused_fit", family="lowrank",
                                          taps="qv", rank=4, scale=scale))
    _, gb, _ = gl.train_step_b(cfg, spec_b, params, adapters, batch)
    for tap in gb:
        for leaf in gb[tap]:
            np.testing.assert_allclose(np.asarray(gm[tap][leaf]),
                                       np.asarray(gb[tap][leaf]),
                                       rtol=5e-3, atol=1e-5)


def test_linear_adapter_equals_full_ft_gradients():
    """ColA(Linear) gradient == d loss / d W of the tapped base weight (§C.3:
    merged linear adapters recover full fine-tuning of those weights)."""
    cfg, cc, params, adapters, batch = _setup("smollm-135m", family="linear")
    spec = gl.make_spec(cfg, ColaConfig(mode="fused_fit", family="linear",
                                        taps="qv"))
    # zero linear adapters => model output identical to base
    adapters = jax.tree.map(jnp.zeros_like, adapters)
    _, g_ad, _ = gl.train_step_b(cfg, spec, params, adapters, batch)
    _, g_ft, _ = gl.train_step_ft(cfg, params, batch)
    np.testing.assert_allclose(
        np.asarray(g_ad["layers.attn.q"]["W"]),
        np.asarray(g_ft["layers"]["attn"]["q"]["w"]), rtol=2e-4, atol=1e-7)
    np.testing.assert_allclose(
        np.asarray(g_ad["layers.attn.v"]["W"]),
        np.asarray(g_ft["layers"]["attn"]["v"]["w"]), rtol=2e-4, atol=1e-7)


def test_fit_loss_gradient_matches_fit_grads():
    cfg, cc, params, adapters, batch = _setup("smollm-135m")
    spec = gl.make_spec(cfg, cc)
    _, data, _ = gl.server_step_a(cfg, spec, params, adapters, batch)
    spec_fit = gl.make_spec(cfg, ColaConfig(mode="fused_fit", family="lowrank",
                                            taps="qv", rank=4))
    g1 = gl.fit_grads(spec_fit, adapters, data)
    g2 = jax.grad(lambda w: gl.fit_loss(spec_fit, w, data, adapters))(adapters)
    for tap in g1:
        for leaf in g1[tap]:
            np.testing.assert_allclose(np.asarray(g1[tap][leaf]),
                                       np.asarray(g2[tap][leaf]),
                                       rtol=5e-3, atol=1e-6)


def test_mlp_adapter_fit_grads_match_direct():
    """Model-agnostic claim: the VJP fit rule works for nonlinear families."""
    cfg, _, params, _, batch = _setup("smollm-135m")
    cc = ColaConfig(mode="faithful_offload", family="mlp", taps="qv", hidden=16)
    adapters = gl.init_adapters(cfg, cc, jax.random.PRNGKey(2))
    adapters = jax.tree.map(
        lambda a: a + 0.02 * jax.random.normal(jax.random.PRNGKey(8), a.shape),
        adapters)
    spec_a = gl.make_spec(cfg, cc)
    _, data, _ = gl.server_step_a(cfg, spec_a, params, adapters, batch)
    ga = gl.fit_grads(spec_a, adapters, data)
    spec_b = gl.make_spec(cfg, ColaConfig(mode="fused_fit", family="mlp",
                                          taps="qv", hidden=16))
    _, gb, _ = gl.train_step_b(cfg, spec_b, params, adapters, batch)
    for tap in gb:
        for leaf in gb[tap]:
            np.testing.assert_allclose(np.asarray(ga[tap][leaf]),
                                       np.asarray(gb[tap][leaf]),
                                       rtol=2e-4, atol=1e-6)
