"""Chunked prefill + paged KV cache (ISSUE 9).

Acceptance invariants:
- chunked prefill (``prefill_chunk=C``) emits exactly the tokens of unchunked
  prefill on every layer plan — bit-identical logits for attention plans,
  token-exact (argmax) with tight logit tolerance for recurrent/moe plans,
  including prompts with an ``S % C != 0`` tail chunk;
- the paged KV layout (``kv_layout="paged"``) is bit-identical to the dense
  chunked run on *every* plan (pool + block table is a relayout, not a
  renumeration), and the fused paged Pallas kernel matches its gather oracle;
- HBM accounting: ``kv_cache_bytes()`` under the paged layout scales with
  blocks actually in use, not the horizon, and a paged engine admits prompts
  longer than the dense engine's old ``max_len`` ceiling with a small pool;
- pool safety: reservation-backed admission, refcounted frees, double frees
  raise, and a drained engine always returns the pool whole
  (``assert_empty``).  Churn-under-faults lives in tests/test_faults.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ColaConfig
from repro.core import gl
from repro.kernels import decode_attention as da
from repro.kernels import ref
from repro.models import model as M
from repro.runtime.kv_pager import BlockPager, PagerError
from repro.runtime.serve_loop import Request, ServeEngine


def _tiny(name="smollm-135m", **over):
    cfg = registry.reduced_config(name)
    base = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
                d_ff=128, vocab_size=128)
    base.update(over)
    return cfg.replace(**{k: v for k, v in base.items() if hasattr(cfg, k)})


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=p) for p in lens]


# one case per layer plan: uniform attn, moe, local/global pairs, uniform ssm,
# hybrid (shared attn over ssm backbone). P is chosen so P % C != 0 — the tail
# chunk is narrower than C and exercises the exact-width recurrent grouping
# and the per-row logit gather of the padded attention group.
PLAN_CASES = {
    "smollm-135m": dict(C=4, P=11, over={}, exact=True),
    # drop-free capacity so chunked routing can't change expert drops; the
    # residual difference is shape-dependent matmul blocking noise
    "qwen3-moe-30b-a3b": dict(C=4, P=9, over=dict(capacity_factor=8.0),
                              exact=False),
    "gemma2-9b": dict(C=4, P=13, over=dict(local_window=6), exact=True),
    "mamba2-370m": dict(C=4, P=11, over=dict(ssm_headdim=16, ssm_state=16),
                        exact=True),
    "zamba2-7b": dict(C=4, P=11, over=dict(ssm_headdim=16, ssm_state=16),
                      exact=True),
}


# ---------------------------------------------------------------------------
# pager unit tests
# ---------------------------------------------------------------------------

def test_pager_reserve_ensure_release_roundtrip():
    pg = BlockPager(n_blocks=8, block_size=4, slots=2, max_len=32)
    assert pg.max_blocks == 8 and pg.blocks_for(9) == 3 and pg.blocks_for(0) == 0
    assert pg.reserve(0, 9)                      # 3 blocks promised
    assert pg.free_unreserved() == 5
    assert pg.ensure(0, 6)                       # pos 0..6 -> 2 blocks
    assert pg.capacity(0) == 8 and pg.blocks_in_use() == 2
    assert pg.free_unreserved() == 5             # drawn from the reservation
    # table maps position // block -> the owned pool block, in order
    assert list(pg.table[0, :2]) == list(pg.owned(0))
    assert pg.ensure(0, 6)                       # idempotent, no new blocks
    assert pg.stats["allocs"] == 2
    pg.release(0)
    assert pg.blocks_in_use() == 0 and pg.capacity(0) == 0
    assert np.all(pg.table[0] == 0)
    pg.assert_empty()
    assert pg.stats["allocs"] == pg.stats["frees"] == 2


def test_pager_reserve_fails_clean_when_pool_promised():
    pg = BlockPager(n_blocks=4, block_size=4, slots=3, max_len=16)
    assert pg.reserve(0, 12)                     # 3 of 4 blocks
    assert not pg.reserve(1, 8)                  # would need 2, only 1 left
    assert pg.stats["reserve_failures"] == 1
    assert pg.free_unreserved() == 1             # failed reserve claims nothing
    assert pg.reserve(1, 4)
    # every free block is now promised: a slot with no reservation cannot
    # allocate even one block, while slot 1 can draw down its own promise
    assert not pg.ensure(2, 0)
    assert pg.ensure(1, 3)
    assert not pg.ensure(1, 4)                   # beyond its reservation
    pg.release(0)
    pg.release(1)
    pg.assert_empty()


def test_pager_release_is_refcounted_and_double_free_raises():
    pg = BlockPager(n_blocks=4, block_size=4, slots=2, max_len=16)
    assert pg.ensure(0, 5)
    blk = pg.owned(0)[0]
    pg.release(0)
    pg._owned[0] = [blk]                         # simulate a corrupted retire
    with pytest.raises(PagerError, match="double free"):
        pg.release(0)


def test_pager_assert_empty_detects_leak():
    pg = BlockPager(n_blocks=4, block_size=4, slots=2, max_len=16)
    assert pg.ensure(1, 0)
    with pytest.raises(PagerError, match="leaked"):
        pg.assert_empty()
    pg.release(1)
    pg.assert_empty()


# ---------------------------------------------------------------------------
# fused paged kernel vs gather oracle; ring oracle vs dense window
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window,softcap", [(None, None), (9, 10.0)])
def test_paged_kernel_matches_oracle(window, softcap):
    """decode_attention_paged (interpret) == ref.sdpa_decode_paged with rows
    at scattered positions, a shuffled block assignment, and a dead slot."""
    rng = np.random.default_rng(0)
    B, H, K, Dh = 4, 8, 2, 64
    bs, nb_pool, nb_tab = 8, 16, 6
    q = jnp.asarray(rng.normal(size=(B, 1, H, Dh)), jnp.float32)
    k_pool = jnp.asarray(rng.normal(size=(nb_pool, bs, K, Dh)), jnp.float32)
    v_pool = jnp.asarray(rng.normal(size=(nb_pool, bs, K, Dh)), jnp.float32)
    positions = np.array([3, 10, 21, 40], np.int32)
    table = np.zeros((B, nb_tab), np.int32)
    it = iter(rng.permutation(nb_pool))
    for b in range(B):
        for j in range(positions[b] // bs + 1):
            table[b, j] = next(it)
    table = jnp.asarray(table)
    positions = jnp.asarray(positions)
    live = jnp.asarray([True, True, False, True])
    assert da.supported_paged(q, k_pool, v_pool, table)

    o_ref = ref.sdpa_decode_paged(q, k_pool, v_pool, positions, table,
                                  live=live, window=window, softcap=softcap)
    o_pal = da.decode_attention_paged(q, k_pool, v_pool, positions, table,
                                      live=live, window=window,
                                      softcap=softcap, interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               atol=1e-5, rtol=1e-5)
    # dead slot is exact zeros, not stale-cache attention
    assert np.all(np.asarray(o_pal)[2] == 0)


def test_ring_oracle_bit_identical_to_dense_window():
    """The rolling ring cache (pairs local layers under the paged layout) with
    position p at ring index p % W_ring reads back bit-identically to the
    dense windowed oracle — including positions that have wrapped the ring."""
    rng = np.random.default_rng(0)
    B, K, Dh, Smax = 4, 2, 64, 32
    W, C = 5, 3
    Wr = W + C - 1
    q = jnp.asarray(rng.normal(size=(B, 1, 2 * K, Dh)), jnp.float32)
    kd = jnp.asarray(rng.normal(size=(B, Smax, K, Dh)), jnp.float32)
    vd = jnp.asarray(rng.normal(size=(B, Smax, K, Dh)), jnp.float32)
    kr = jnp.zeros((B, Wr, K, Dh), jnp.float32)
    vr = jnp.zeros((B, Wr, K, Dh), jnp.float32)
    pos = np.array([2, 7, 13, 25], np.int32)     # 13, 25 have wrapped (> Wr)
    for b in range(B):
        for p in range(pos[b] + 1):
            kr = kr.at[b, p % Wr].set(kd[b, p])
            vr = vr.at[b, p % Wr].set(vd[b, p])
    live = jnp.asarray([True, True, False, True])
    o_dense = ref.sdpa_decode(q, kd, vd, jnp.asarray(pos), live=live, window=W)
    o_ring = ref.sdpa_decode_ring(q, kr, vr, jnp.asarray(pos), live=live,
                                  window=W)
    np.testing.assert_array_equal(np.asarray(o_ring), np.asarray(o_dense))


# ---------------------------------------------------------------------------
# model level: chunked == full prefill; paged == dense (every layer plan)
# ---------------------------------------------------------------------------

def _chunk_run(cfg, params, prompt, cache, *, C, slot, slots, recurrent,
               pager=None):
    """Drive decode_step chunk-by-chunk the way the engine does: recurrent
    plans get exact-width tails, attention plans a padded width-C group with
    the per-row logit gather. Returns the last real token's logits."""
    P = len(prompt)
    consumed, lg_last = 0, None
    while consumed < P:
        c = min(C, P - consumed)
        width = c if recurrent else C
        toks = np.zeros((slots, width), np.int32)
        toks[slot, :c] = prompt[consumed:consumed + c]
        if pager is not None:
            assert pager.ensure(slot, consumed + width - 1)
        pos = np.zeros((slots,), np.int32)
        pos[slot] = consumed
        live = np.zeros((slots,), bool)
        live[slot] = True
        kw = ({"block_table": jnp.asarray(pager.table)}
              if pager is not None else {})
        lg, cache = M.decode_step(
            cfg, params, {"tokens": jnp.asarray(toks),
                          "positions": jnp.asarray(pos)},
            cache, live=jnp.asarray(live), **kw)
        lg_last = np.asarray(lg[slot, c - 1])
        consumed += c
    return lg_last


@pytest.mark.parametrize("name", sorted(PLAN_CASES))
def test_chunked_matches_prefill_and_paged_matches_dense(name):
    case = PLAN_CASES[name]
    C, P = case["C"], case["P"]
    assert P % C != 0                            # tail chunk narrower than C
    cfg = _tiny(name, **case["over"])
    params = M.init(cfg, jax.random.PRNGKey(0))
    prompt = _prompts(cfg, (P,), seed=1)[0]
    slots, max_len, s = 3, 32, 1
    recurrent = M.has_recurrent_state(cfg)

    lg_full, _ = M.prefill(cfg, params, {"tokens": jnp.asarray(prompt[None, :])})
    lg_full = np.asarray(lg_full[0, 0])

    lg_d = _chunk_run(cfg, params, prompt, M.init_cache(cfg, slots, max_len),
                      C=C, slot=s, slots=slots, recurrent=recurrent)
    assert int(np.argmax(lg_d)) == int(np.argmax(lg_full))
    if case["exact"] and not recurrent:
        np.testing.assert_array_equal(lg_d, lg_full)
    else:
        np.testing.assert_allclose(lg_d, lg_full, atol=1e-3)

    # paged relayout: bit-identical to the dense chunked run on every plan
    plan = M.layer_plan(cfg)
    ring_len = cfg.local_window + C - 1 if plan[0] == "pairs" else None
    pager = BlockPager(n_blocks=16, block_size=8, slots=slots, max_len=max_len)
    assert pager.reserve(s, P)
    cache_p = M.init_cache(cfg, slots, max_len, kv_layout="paged",
                           kv_blocks=16, kv_block=8, ring_len=ring_len)
    lg_p = _chunk_run(cfg, params, prompt, cache_p, C=C, slot=s, slots=slots,
                      recurrent=recurrent, pager=pager)
    np.testing.assert_array_equal(lg_p, lg_d)


# ---------------------------------------------------------------------------
# engine level: every serving mode emits identical tokens
# ---------------------------------------------------------------------------

def _run_modes(cfg, params, prompts, banks=None, max_new=5, slots=4,
               max_len=64, **extra_modes):
    def run(**kw):
        eng = ServeEngine(cfg, params, slots=slots, max_len=max_len,
                          user_adapters=banks, **kw)
        reqs = [Request(rid=i, user=(i % 2 if banks else 0), prompt=p,
                        max_new=max_new) for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_idle()
        assert all(r.done and r.status == "done" for r in reqs)
        return [r.out for r in reqs], eng
    return run


def test_engine_modes_token_identical():
    """batched / reference / chunked / paged / burst / paged+burst all emit
    the same tokens (prompt lens include 1 and a chunk-straddling 21)."""
    cfg = _tiny()
    params = M.init(cfg, jax.random.PRNGKey(0))
    prompts = _prompts(cfg, (1, 5, 9, 13, 21))
    run = _run_modes(cfg, params, prompts, max_new=6)
    base, _ = run(prefill_mode="batched")
    modes = {
        "reference": dict(prefill_mode="reference"),
        "chunked": dict(prefill_chunk=4),
        "paged": dict(prefill_chunk=4, kv_layout="paged", kv_block=8),
        "burst": dict(decode_burst=4),
        "paged_burst": dict(prefill_chunk=4, kv_layout="paged", kv_block=8,
                            decode_burst=4),
    }
    for mode, kw in modes.items():
        out, eng = run(**kw)
        assert out == base, f"{mode} != batched"
        if eng.pager is not None:
            eng.pager.assert_empty()
            assert eng.stats["kv_allocs"] == eng.stats["kv_frees"]


@pytest.mark.parametrize("name", sorted(PLAN_CASES))
def test_engine_chunked_and_paged_match_unchunked(name):
    case = PLAN_CASES[name]
    cfg = _tiny(name, **case["over"])
    params = M.init(cfg, jax.random.PRNGKey(0))
    banks = None
    if name == "smollm-135m":                    # adapters ride along once
        cc = ColaConfig(mode="lora", family="lowrank", taps="qv", rank=4)
        banks = [gl.init_adapters(cfg, cc,
                                  jax.random.fold_in(jax.random.PRNGKey(7), u))
                 for u in range(2)]
    prompts = _prompts(cfg, (1, 5, 9, 14))
    run = _run_modes(cfg, params, prompts, banks=banks)
    base, _ = run(prefill_mode="batched")
    chk, _ = run(prefill_chunk=case["C"])
    assert chk == base, f"{name}: chunked != unchunked"
    pg, eng = run(prefill_chunk=case["C"], kv_layout="paged", kv_block=8)
    assert pg == base, f"{name}: paged != dense"
    eng.pager.assert_empty()


# ---------------------------------------------------------------------------
# capacity: virtual horizon, max_prompt, HBM proportional to used blocks
# ---------------------------------------------------------------------------

def test_paged_engine_admits_prompt_beyond_dense_horizon():
    """With a 40-block pool the paged engine serves a 97-token prompt under a
    max_len=256 virtual horizon — a prompt the dense max_len=64 engine
    rejects outright — while peak pool use stays far below the horizon."""
    cfg = _tiny()
    params = M.init(cfg, jax.random.PRNGKey(0))
    prompt = _prompts(cfg, (97,), seed=3)[0]

    dense = ServeEngine(cfg, params, slots=4, max_len=64)
    rej = Request(rid=0, user=0, prompt=prompt, max_new=4)
    dense.submit(rej)
    assert rej.done and "prompt length 97" in rej.status

    eng = ServeEngine(cfg, params, slots=4, max_len=256, prefill_chunk=8,
                      kv_layout="paged", kv_block=8, kv_blocks=40)
    r = Request(rid=1, user=0, prompt=prompt, max_new=4)
    eng.submit(r)
    eng.run_until_idle()
    assert r.status == "done" and len(r.out) == 4
    eng.pager.assert_empty()
    # pool sized for the request, not slots * horizon (= 128 blocks)
    assert eng.stats["kv_blocks_peak"] <= eng.pager.blocks_for(97 + 8)


def test_max_prompt_boundary_and_rejection_reason():
    cfg = _tiny()
    params = M.init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=2, max_len=64, max_prompt=20)
    ok = Request(rid=0, user=0, prompt=_prompts(cfg, (20,))[0], max_new=2)
    bad = Request(rid=1, user=0, prompt=_prompts(cfg, (21,))[0], max_new=2)
    eng.submit(ok)
    eng.submit(bad)
    assert not ok.done
    assert bad.done and bad.status.startswith("rejected: ")
    assert "prompt length 21 > max_prompt 20" in bad.status
    assert "max_len=64" in bad.status
    eng.run_until_idle()
    assert ok.status == "done"
    # default max_prompt remains the dense-compatible max_len - 1
    assert ServeEngine(cfg, params, slots=2, max_len=64).max_prompt == 63


def test_paged_cache_bytes_proportional_to_blocks_in_use():
    """kv_cache_bytes under the paged layout is affine in blocks_in_use (the
    non-pool leaves are a fixed intercept) and far below the dense layout's
    horizon-scaled footprint at the same max_len."""
    cfg = _tiny()
    params = M.init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=4, max_len=256, prefill_chunk=8,
                      kv_layout="paged", kv_block=8, kv_blocks=64)
    dense = ServeEngine(cfg, params, slots=4, max_len=256)
    assert eng.kv_cache_bytes() < dense.kv_cache_bytes() / 100

    r = Request(rid=0, user=0, prompt=_prompts(cfg, (33,), seed=3)[0],
                max_new=8)
    eng.submit(r)
    samples = []
    while not r.done:
        eng.tick()
        samples.append((eng.stats["kv_blocks_in_use"], eng.kv_cache_bytes()))
    counts = sorted({c for c, _ in samples})
    # KV is written for the prompt plus every generated token except the last
    # (never fed back): P + max_new - 1 positions
    assert len(counts) >= 2 and counts[-1] == eng.pager.blocks_for(33 + 8 - 1)
    by_count = dict(samples)
    slope = ((by_count[counts[-1]] - by_count[counts[0]])
             / (counts[-1] - counts[0]))
    assert slope > 0
    for c, b in samples:
        assert b == by_count[counts[0]] + (c - counts[0]) * slope
    eng.pager.assert_empty()


def test_queued_request_waits_for_pool_capacity():
    """When the pool can't cover a second request's worst case, admission
    leaves it queued (reserve fails clean) until the first retires."""
    cfg = _tiny()
    params = M.init(cfg, jax.random.PRNGKey(0))
    # 6 blocks x 8 = 48 positions; each request reserves ceil(28/4)*4 = 28
    # positions = 4 blocks, so only one fits at a time
    eng = ServeEngine(cfg, params, slots=2, max_len=64, prefill_chunk=4,
                      kv_layout="paged", kv_block=8, kv_blocks=6)
    reqs = [Request(rid=i, user=0, prompt=_prompts(cfg, (26,), seed=i)[0],
                    max_new=2) for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.tick()
    assert sum(r is not None for r in eng.active) == 1 and len(eng.queue) == 1
    assert eng.stats["kv_reserve_failures"] >= 1
    eng.run_until_idle()
    assert all(r.status == "done" and len(r.out) == 2 for r in reqs)
    eng.pager.assert_empty()
