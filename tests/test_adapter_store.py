"""Tiered adapter store: host tier + LRU device residency + clustering.

Core invariants under test:
- serving through an R-row resident cache (R << U) with mid-flight evictions
  emits tokens *bit-identical* to the all-resident engine (f32 and int8);
- pinned users (live/queued slots) are never evicted; admission waits rather
  than deadlocking when every row is pinned;
- task-similarity clusters share one resident row, and a member's own
  ``install_adapters`` splits them off copy-on-write without perturbing the
  other members;
- ``publish_banks`` skips (legacy bank) or registers (store) users the engine
  has never seen, and an `OffloadChannel.on_commit` hook pushes validated fits
  straight into serving.
"""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ColaConfig
from repro.core import gl
from repro.core.channel import OffloadChannel
from repro.core.merge import merge_adapter_pytrees
from repro.kernels.multi_lora import dequant_rows, quant_rows
from repro.models import model as M
from repro.runtime.adapter_store import AdapterStore, _cosine
from repro.runtime.serve_loop import (Request, ServeEngine, publish_banks,
                                      stack_user_adapters)


def _tiny():
    cfg = registry.reduced_config("smollm-135m").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=128)
    key = jax.random.PRNGKey(0)
    return cfg, M.init(cfg, key), key


_CC = ColaConfig(mode="lora", family="lowrank", taps="qv", rank=4)


def _bank(cfg, key, seed, jitter=0.1):
    ad = gl.init_adapters(cfg, _CC, jax.random.fold_in(key, seed))
    return jax.tree.map(lambda a: a + jitter * jax.random.normal(
        jax.random.fold_in(key, 1000 + seed), a.shape), ad)


def _banks(cfg, key, n):
    return [_bank(cfg, key, u) for u in range(n)]


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=p) for p in lens]


def _serve(eng, prompts, users, max_new=5):
    reqs = [Request(rid=i, user=u, prompt=p, max_new=max_new)
            for i, (u, p) in enumerate(zip(users, prompts))]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    return [r.out for r in reqs]


# ---------------------------------------------------------------------------
# satellite: stack_user_adapters input validation
# ---------------------------------------------------------------------------

def test_stack_user_adapters_empty_raises():
    with pytest.raises(ValueError, match="empty list"):
        stack_user_adapters([])


def test_stack_user_adapters_mismatched_structure_raises():
    cfg, params, key = _tiny()
    a0 = _bank(cfg, key, 0)
    cc_r8 = ColaConfig(mode="lora", family="lowrank", taps="qv", rank=8)
    a1 = gl.init_adapters(cfg, cc_r8, key)   # different rank -> shape mismatch
    with pytest.raises(ValueError, match="user 1 adapter structure"):
        stack_user_adapters([a0, a1])


# ---------------------------------------------------------------------------
# store unit tests (no engine)
# ---------------------------------------------------------------------------

def test_store_lru_eviction_order_and_counters():
    cfg, params, key = _tiny()
    st = AdapterStore.from_users(_banks(cfg, key, 4), resident=2)
    assert st.ensure_resident([0])[0] == st.ensure_resident([0])[0]
    st.ensure_resident([1])
    assert st.counters["hits"] == 1 and st.counters["misses"] == 2
    # 0 is least-recently-used; admitting 2 must evict 0, not 1
    st.ensure_resident([2])
    assert st.counters["evictions"] == 1
    assert st.resident_index(0) is None
    assert st.resident_index(1) is not None
    # touching 1 then admitting 3 evicts 2
    st.ensure_resident([1, 3])
    assert st.resident_index(2) is None and st.resident_index(3) is not None
    m = st.metrics()
    assert m["resident_users"] == 2 and m["host_users"] == 4
    assert 0.0 < m["hit_rate"] < 1.0
    assert m["fetch_time"] > 0.0


def test_store_resident_bytes_bounded_by_R():
    cfg, params, key = _tiny()
    banks = _banks(cfg, key, 16)
    st = AdapterStore.from_users(banks, resident=2)
    dense = stack_user_adapters(banks)
    dense_bytes = sum(l.nbytes for l in jax.tree.leaves(dense))
    assert st.resident_bytes() == dense_bytes * 2 // 16
    # host tier is numpy, device tier bounded by R regardless of U
    st2 = AdapterStore.from_users(banks, resident=2, store="int8")
    assert st2.resident_bytes() < st.resident_bytes()


def test_store_pinned_rows_never_evicted():
    cfg, params, key = _tiny()
    st = AdapterStore.from_users(_banks(cfg, key, 5), resident=2)
    assert st.acquire(0)
    row0 = st.ensure_resident([0])[0]
    # churn through other users: user 0's row must survive every eviction
    for u in (1, 2, 3, 4):
        st.ensure_resident([u])
        assert st.resident_index(0) == row0
    # a second pin exhausts capacity: acquiring a third distinct user fails
    assert st.acquire(1)
    assert not st.acquire(2)
    st.release(0)
    assert st.acquire(2)
    # refcounting: double-acquire needs double-release
    assert st.acquire(2) and st.pinned_count() == 2
    st.release(2)
    assert st.pinned_count() == 2
    st.release(2)
    assert st.pinned_count() == 1


def test_store_all_rows_pinned_raises_on_fetch():
    cfg, params, key = _tiny()
    st = AdapterStore.from_users(_banks(cfg, key, 3), resident=1)
    assert st.acquire(0)
    st.ensure_resident([0])
    with pytest.raises(RuntimeError, match="pinned"):
        st._fetch(("user", 1))


def test_store_rejects_mismatched_registration():
    cfg, params, key = _tiny()
    st = AdapterStore.from_users(_banks(cfg, key, 2), resident=2)
    cc_r8 = ColaConfig(mode="lora", family="lowrank", taps="qv", rank=8)
    with pytest.raises(ValueError, match="store\\s+template"):
        st.register(7, gl.init_adapters(cfg, cc_r8, key))


# ---------------------------------------------------------------------------
# residency churn: R << U serving is bit-identical to all-resident
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bank_store", ["f32", "int8"])
def test_store_serving_bit_identical_under_churn(bank_store):
    """U=12 users through R=4 resident rows and 3 slots: evictions happen
    mid-flight (users repeat), yet per-request tokens match the all-resident
    (R=U) engine bit-for-bit, and device adapter bytes are bounded by R."""
    cfg, params, key = _tiny()
    banks = _banks(cfg, key, 12)
    prompts = _prompts(cfg, [5 + (i % 7) for i in range(24)])
    users = [(5 * i) % 12 for i in range(24)]   # strided reuse -> churn

    def run(**kw):
        eng = ServeEngine(cfg, params, slots=3, max_len=64,
                          user_adapters=banks, bank_store=bank_store, **kw)
        return _serve(eng, prompts, users), eng

    o_full, e_full = run()
    o_store, e_store = run(resident_slots=4)
    assert o_store == o_full
    st = e_store.stats
    assert st["store_evictions"] > 0 and st["store_misses"] > 0
    assert st["store_hits"] + st["store_misses"] > 0
    assert st["store_fetch_time"] > 0.0
    # device-resident adapter bytes scale with R=4, not U=12
    full_bytes = sum(l.nbytes for l in jax.tree.leaves(e_full.bank))
    assert st["store_resident_bytes"] == full_bytes * 4 // 12
    # every pin was released at completion
    assert st["store_pinned"] == 0
    assert e_store.throughput()["store"]["hit_rate"] >= 0.0


def test_store_admission_waits_when_all_rows_pinned():
    """R == slots and every queued request is a distinct user: admission must
    stall (never evict a live user's row) and still drain the queue."""
    cfg, params, key = _tiny()
    banks = _banks(cfg, key, 6)
    prompts = _prompts(cfg, [6] * 6)
    eng = ServeEngine(cfg, params, slots=2, max_len=64, user_adapters=banks,
                      resident_slots=2)
    outs = _serve(eng, prompts, list(range(6)), max_new=4)
    assert eng.stats["completed"] == 6
    assert all(len(o) == 4 for o in outs)
    # matches the all-resident engine despite the admission stalls
    ref = ServeEngine(cfg, params, slots=2, max_len=64, user_adapters=banks)
    assert outs == _serve(ref, prompts, list(range(6)), max_new=4)


def test_store_reference_prefill_mode_matches_batched():
    cfg, params, key = _tiny()
    banks = _banks(cfg, key, 8)
    prompts = _prompts(cfg, (1, 5, 9, 13))
    users = [1, 7, 3, 1]
    outs = {}
    for mode in ("batched", "reference"):
        eng = ServeEngine(cfg, params, slots=2, max_len=64,
                          user_adapters=banks, resident_slots=3,
                          prefill_mode=mode)
        outs[mode] = _serve(eng, prompts, users)
    assert outs["batched"] == outs["reference"]


def test_store_burst_decode_bit_identical():
    cfg, params, key = _tiny()
    banks = _banks(cfg, key, 8)
    prompts = _prompts(cfg, (5, 9, 13))
    users = [0, 5, 0]
    eng1 = ServeEngine(cfg, params, slots=3, max_len=64, user_adapters=banks,
                       resident_slots=4)
    eng8 = ServeEngine(cfg, params, slots=3, max_len=64, user_adapters=banks,
                       resident_slots=4, decode_burst=8)
    assert (_serve(eng1, prompts, users, max_new=17)
            == _serve(eng8, prompts, users, max_new=17))


# ---------------------------------------------------------------------------
# task-similarity clustering + copy-on-write splits
# ---------------------------------------------------------------------------

def _clustered_setup(mode="shared"):
    cfg, params, key = _tiny()
    base = jax.tree.map(lambda a: a + 0.2, _bank(cfg, key, 0, jitter=0.0))
    banks = [
        base,                                      # users 0,1: one task
        jax.tree.map(lambda a: a * 1.01, base),
        _bank(cfg, key, 2, jitter=0.3),            # users 2,3: distinct tasks
        _bank(cfg, key, 3, jitter=0.4),
    ]
    eng = ServeEngine(cfg, params, slots=2, max_len=64, user_adapters=banks,
                      resident_slots=3, cluster_threshold=0.95,
                      cluster_mode=mode)
    return cfg, params, key, base, banks, eng


@pytest.mark.parametrize("mode", ["shared", "merged"])
def test_clustering_maps_similar_users_to_one_row(mode):
    cfg, params, key, base, banks, eng = _clustered_setup(mode)
    st = eng.store
    cid = st.cluster_of(0)
    assert cid is not None and st.cluster_of(1) == cid
    assert st.cluster_of(2) is None and st.cluster_of(3) is None
    p = _prompts(cfg, (7,))[0]
    # cluster members share an adapter -> identical tokens, one resident row
    o0, o1 = _serve(eng, [p, p], [0, 1])
    assert o0 == o1
    assert st.resident_index(0) == st.resident_index(1)
    assert eng.stats["store_hits"] >= 1   # the second member's touch is a hit


def test_cow_split_does_not_perturb_cluster_members():
    cfg, params, key, base, banks, eng = _clustered_setup()
    prompts = _prompts(cfg, (7,))
    before0 = _serve(eng, prompts, [0])[0]
    before1 = _serve(eng, prompts, [1])[0]
    assert before0 == before1
    # user 1 installs their own fit: COW split off the cluster
    new = jax.tree.map(lambda a: a - 0.3, base)
    assert eng.install_adapters(1, new, version=1)
    assert eng.store.cluster_of(1) is None and eng.store.cluster_of(0) is not None
    assert eng.store.counters["splits"] == 1
    after0 = _serve(eng, prompts, [0])[0]
    after1 = _serve(eng, prompts, [1])[0]
    assert after0 == before0, "cluster member perturbed by peer's split"
    assert after1 != before1, "split user still serving the cluster adapter"
    # the split user's tokens now match a dedicated engine on the new bank
    solo = ServeEngine(cfg, params, slots=1, max_len=64, user_adapters=[new])
    assert after1 == _serve(solo, prompts, [0])[0]


def test_merged_cluster_serves_member_mean():
    cfg, params, key, base, banks, eng = _clustered_setup(mode="merged")
    merged = merge_adapter_pytrees([banks[0], banks[1]])
    solo = ServeEngine(cfg, params, slots=1, max_len=64, user_adapters=[merged])
    prompts = _prompts(cfg, (7,))
    assert _serve(eng, prompts, [0])[0] == _serve(solo, prompts, [0])[0]


def test_merge_adapter_pytrees_units():
    a = {"t": {"A": np.full((2, 2), 1.0, np.float32)}}
    b = {"t": {"A": np.full((2, 2), 3.0, np.float32)}}
    m = merge_adapter_pytrees([a, b])
    np.testing.assert_allclose(m["t"]["A"], 2.0)
    w = merge_adapter_pytrees([a, b], weights=[0.75, 0.25])
    np.testing.assert_allclose(w["t"]["A"], 1.5)
    with pytest.raises(ValueError, match="at least one"):
        merge_adapter_pytrees([])
    with pytest.raises(ValueError, match="structures differ"):
        merge_adapter_pytrees([a, {"t": {"B": np.zeros((2, 2), np.float32)}}])
    with pytest.raises(ValueError, match="shapes differ"):
        merge_adapter_pytrees([a, {"t": {"A": np.zeros((2, 3), np.float32)}}])


def test_cosine_zero_norm_convention():
    z = np.zeros(3)
    v = np.ones(3)
    assert _cosine(z, z) == 1.0 and _cosine(z, v) == 0.0
    assert _cosine(v, v) == pytest.approx(1.0)


def test_dequant_rows_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8)), jnp.float32)
    q, s = quant_rows(x)
    back = dequant_rows(q, s)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               atol=float(jnp.max(s)) + 1e-6)


# ---------------------------------------------------------------------------
# publish_banks / channel interop
# ---------------------------------------------------------------------------

def _fake_channel(user, version, adapters):
    return types.SimpleNamespace(user=user, version=version, adapters=adapters)


def test_publish_banks_skips_out_of_range_users_legacy():
    """Satellite: a channel whose user id is outside the dense bank must be
    skipped and counted, not crash with IndexError."""
    cfg, params, key = _tiny()
    banks = _banks(cfg, key, 2)
    eng = ServeEngine(cfg, params, slots=2, max_len=32, user_adapters=banks)
    good = jax.tree.map(lambda a: a + 0.1, banks[0])
    chans = [_fake_channel(5, 3, good),       # out of range -> skipped
             _fake_channel(-1, 3, good),      # negative -> skipped
             _fake_channel(1, 3, good)]       # in range -> installed
    assert publish_banks(eng, chans) == 1
    assert eng.stats["bank_unknown_user"] == 2
    assert eng.stats["bank_installs"] == 1
    assert eng.bank_versions.tolist() == [0, 3]


def test_publish_banks_registers_unknown_users_into_store():
    cfg, params, key = _tiny()
    banks = _banks(cfg, key, 2)
    eng = ServeEngine(cfg, params, slots=2, max_len=64, user_adapters=banks,
                      resident_slots=2)
    # user 7 was never part of the engine's construction
    r = Request(rid=0, user=7, prompt=np.arange(5) % cfg.vocab_size, max_new=3)
    eng.submit(r)
    assert r.status.startswith("rejected: unknown user")
    newcomer = _bank(cfg, key, 7)
    assert publish_banks(eng, [_fake_channel(7, 0, newcomer)]) == 1
    assert eng.store.knows(7) and eng.store.version(7) == 0
    # ...and is now servable, matching a dedicated engine on the same bank
    out = _serve(eng, _prompts(cfg, (6,)), [7])[0]
    solo = ServeEngine(cfg, params, slots=1, max_len=64,
                       user_adapters=[newcomer])
    assert out == _serve(solo, _prompts(cfg, (6,)), [0])[0]
    # a later version bump installs; a replay is rejected
    assert publish_banks(eng, [_fake_channel(7, 2, newcomer)]) == 1
    assert publish_banks(eng, [_fake_channel(7, 2, newcomer)]) == 0


def test_store_install_rejects_nonfinite_and_stale():
    cfg, params, key = _tiny()
    banks = _banks(cfg, key, 2)
    eng = ServeEngine(cfg, params, slots=2, max_len=64, user_adapters=banks,
                      resident_slots=2)
    poisoned = jax.tree.map(lambda a: a * np.nan, banks[0])
    assert not eng.install_adapters(0, poisoned, version=1)
    assert not eng.install_adapters(0, banks[0], version=0)   # stale
    assert eng.stats["bank_rejected"] == 2
    cc_r8 = ColaConfig(mode="lora", family="lowrank", taps="qv", rank=8)
    assert not eng.install_adapters(0, gl.init_adapters(cfg, cc_r8, key), 5)
    assert eng.stats["bank_rejected"] == 3


class _BankOffloader:
    """Duck-typed Offloader whose bank is a real engine-shaped adapter pytree;
    every fit nudges each leaf (so commits are validated version bumps)."""

    def __init__(self, adapters):
        self.adapters = adapters
        self.opt_state = {}
        self.buffers: dict[str, list] = {}
        self._pushes = 0

    @property
    def ready(self):
        return bool(self.buffers)

    def push(self, data):
        self.buffers.setdefault("t", []).append(data)
        self._pushes += 1

    def maybe_fit(self):
        if not self.ready:
            return None
        self.adapters = jax.tree.map(lambda a: a + 0.01, self.adapters)
        self.buffers.clear()
        return self.adapters


def test_channel_on_commit_pushes_into_serving():
    """The push-based publication path: a channel's validated commit lands in
    the engine's host tier via on_commit, no publish_banks sweep needed."""
    cfg, params, key = _tiny()
    banks = _banks(cfg, key, 1)
    eng = ServeEngine(cfg, params, slots=1, max_len=64, user_adapters=banks,
                      resident_slots=1)
    seen = []

    def commit(user, version, adapters):
        seen.append((user, version))
        assert eng.install_adapters(user, adapters, version)

    ch = OffloadChannel(_BankOffloader(banks[0]), user=0, on_commit=commit)
    ch.push({"t": (np.ones(4, np.float32), np.ones(4, np.float32))})
    committed = ch.fit_round()
    assert committed is not None
    assert seen == [(0, 1)]
    assert eng.store.version(0) == 1
    assert eng.stats["bank_installs"] == 1
    # the pushed bank is what the engine now serves with
    out = _serve(eng, _prompts(cfg, (6,)), [0])[0]
    solo = ServeEngine(cfg, params, slots=1, max_len=64,
                       user_adapters=[committed])
    assert out == _serve(solo, _prompts(cfg, (6,)), [0])[0]
