"""Distribution layer tests. Multi-device cases run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (jax locks the device count
at first init, so the main test process stays single-device)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout


def test_sharded_train_step_matches_single_device():
    """The pjit train step on a (2,4) mesh computes the same loss/grads as the
    single-device step — distribution never changes semantics."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import registry
        from repro.configs.base import ColaConfig
        from repro.core import gl
        from repro.distributed import sharding as sh, steps
        from repro.launch.mesh import make_mesh
        from repro.models import model as M

        cfg = registry.reduced_config('smollm-135m').replace(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
            d_ff=128, vocab_size=128)
        key = jax.random.PRNGKey(0)
        params = M.init(cfg, key)
        cc = ColaConfig(mode='fused_fit', family='lowrank', taps='qv', rank=4)
        adapters = gl.init_adapters(cfg, cc, key)
        batch = {'tokens': jax.random.randint(key, (8, 16), 0, 128),
                 'labels': jax.random.randint(key, (8, 16), 0, 128)}
        spec = gl.make_spec(cfg, cc)
        loss1, g1, _ = gl.train_step_b(cfg, spec, params, adapters, batch)

        mesh = make_mesh(2, 4)
        with mesh:
            fn, (ps, ash, _), _ = steps.make_train_step(cfg, cc, mesh)
            bs = sh.batch_shardings(mesh, jax.eval_shape(lambda: batch))
            jitted = jax.jit(fn, in_shardings=(ps, ash, bs))
            loss2, g2 = jitted(params, adapters, batch)
        assert np.allclose(float(loss1), float(loss2), rtol=1e-5), (loss1, loss2)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-3, atol=1e-5)
        print('OK devices=', len(jax.devices()))
    """)
    assert "OK devices= 8" in out


def test_multipod_mesh_and_serve_step():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import registry
        from repro.distributed import sharding as sh, steps
        from repro.models import model as M
        mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
        cfg = registry.reduced_config('mistral-nemo-12b').replace(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
            d_ff=128, vocab_size=128)
        params = M.init(cfg, jax.random.PRNGKey(0))
        B, Smax = 8, 32
        cache = M.init_cache(cfg, B, Smax)
        with mesh:
            fn, ps = steps.make_serve_step(cfg, mesh)
            cache_sh, tok_sh = steps.serve_shardings(cfg, mesh, B, Smax)
            jitted = jax.jit(fn, in_shardings=(ps, cache_sh, tok_sh))
            batch = {'tokens': jnp.zeros((B, 1), jnp.int32),
                     'positions': jnp.zeros((B,), jnp.int32)}
            toks, cache2 = jitted(params, cache, batch)
        # single device reference
        logits, cache_ref = M.decode_step(cfg, params, batch, cache)
        ref_toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(toks), np.asarray(ref_toks))
        print('OK multipod')
    """)
    assert "OK multipod" in out


def test_param_shardings_divisibility():
    """Every assigned arch's param sharding rules produce valid shardings on
    the production mesh shape (divisibility-guarded)."""
    out = run_sub("""
        import jax
        from repro.configs import registry
        from repro.distributed import sharding as sh, steps
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        for arch in registry.ASSIGNED:
            cfg = registry.get_config(arch)
            shapes = steps.shaped_params(cfg)
            shards = sh.params_shardings(mesh, shapes)
            def check(leaf, s):
                for dim, spec in zip(leaf.shape, s.spec):
                    if spec is None:
                        continue
                    axes = (spec,) if isinstance(spec, str) else spec
                    n = 1
                    for a in axes:
                        n *= mesh.shape[a]
                    assert dim % n == 0, (arch, leaf.shape, s.spec)
            jax.tree.map(check, shapes, shards)
        print('OK shardings')
    """)
    assert "OK shardings" in out
