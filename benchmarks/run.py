"""Benchmark harness: one module per paper table/figure + kernel benches +
dry-run roofline summary. Prints CSV-ish blocks; ``python -m benchmarks.run``.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = [
    ("table1_memory", "paper Table 1: computation-space complexity"),
    ("equivalence", "paper Tables 2/3/6: method equivalence"),
    ("from_scratch", "paper C.3/Table 9: learning from scratch"),
    ("interval", "paper C.4: adaptation interval ablation"),
    ("collaboration", "paper Table 4: K-user collaboration"),
    ("compute_eval", "paper Tables 10-18: computation evaluation"),
    ("serve_throughput", "FTaaS serving: batched vs single-row prefill"),
    ("kernels_bench", "kernel micro-benchmarks"),
    ("roofline_summary", "dry-run roofline table (reads dryrun_*.jsonl)"),
]


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None, help="comma-separated suite names")
    args = p.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    failures = []
    for name, desc in SUITES:
        if only and name not in only:
            continue
        print(f"\n===== {name}: {desc} =====", flush=True)
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            mod.run(lambda *a: print(*a, flush=True))
            print(f"[{name}] done in {time.time()-t0:.1f}s")
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        print("\nFAILED suites:", failures)
        return 1
    print("\nall benchmark suites passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
