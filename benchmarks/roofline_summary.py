"""Dry-run roofline table: reads dryrun_single.jsonl (and the multi-pod proof
file when present) and prints the §Roofline table — all three terms per
(arch x shape), dominant bottleneck, MODEL_FLOPS/HLO_FLOPs ratio."""
from __future__ import annotations

import json
import os

from benchmarks.common import fmt_row

FILES = ["dryrun_single.jsonl", "dryrun_multi.jsonl", "dryrun_perf.jsonl"]


def load_records():
    recs = []
    for f in FILES:
        if os.path.exists(f):
            with open(f) as fh:
                for line in fh:
                    try:
                        recs.append(json.loads(line))
                    except json.JSONDecodeError:
                        pass
    return recs


def run(report):
    recs = load_records()
    if not recs:
        report("# no dryrun_*.jsonl found — run "
               "`PYTHONPATH=src python -m repro.launch.dryrun --all`")
        return
    # dedupe: keep last record per (arch, shape, mesh, mode)
    seen = {}
    for r in recs:
        seen[(r["arch"], r["shape"], r["mesh"], r.get("mode", ""))] = r
    report("# roofline terms per (arch x shape x mesh); seconds per step")
    report("# rows marked ~ carry rolled-program (approx) costs: multi-pod "
           "records are compile+memory proofs; exact costs are single-pod")
    report(fmt_row("arch", "shape", "mesh", "t_compute", "t_memory",
                   "t_collective", "bottleneck", "useful_ratio",
                   "peak_GB", "peak_tpu_GB"))
    for (arch, shape, mesh, mode), r in sorted(seen.items()):
        mem = r.get("memory", {})
        approx = "" if r.get("exact_costs") else "~"
        report(fmt_row(
            arch + approx, shape, mesh,
            f"{r['t_compute']:.3e}", f"{r['t_memory']:.3e}",
            f"{r['t_collective']:.3e}", r["bottleneck"],
            f"{r.get('useful_ratio', 0):.3f}",
            f"{mem.get('peak_bytes_per_device', 0)/2**30:.2f}",
            f"{mem.get('peak_corrected_tpu', 0)/2**30:.2f}"))
    n_over = sum(1 for r in seen.values()
                 if r.get("memory", {}).get("peak_corrected_tpu", 0)
                 > 16 * 2**30)
    report(f"# cells with TPU-corrected peak > 16GB (v5e HBM): {n_over}")
