"""Paper Tables 2/3/6 analogue: ColA(LowRank) matches LoRA; ColA(Linear)/
ColA(MLP) can outperform; all modes trained on the same synthetic LM task.

(The GLUE/S2S datasets are not available offline; the *claims* under test are
about optimization equivalence and adapter-family capacity, which the
synthetic bigram task exposes.)"""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_cfg, fmt_row, train_curve
from repro.configs.base import ColaConfig


def run(report):
    cfg = bench_cfg()
    steps = 60
    report("# Tables 2/3 analogue: final train loss per method (synthetic LM)")
    report(fmt_row("method", "trainable", "loss_start", "loss_final"))

    runs = {
        "ft": ColaConfig(mode="ft"),
        "lora_r8": ColaConfig(mode="lora", family="lowrank", rank=8, taps="qv"),
        "cola_lowrank_unmerged": ColaConfig(mode="faithful_offload",
                                            family="lowrank", rank=8, taps="qv"),
        "cola_lowrank_merged": ColaConfig(mode="faithful_offload",
                                          family="lowrank", rank=8, taps="qv",
                                          merged=True),
        "cola_linear_merged": ColaConfig(mode="faithful_offload",
                                         family="linear", taps="qv",
                                         merged=True),
        "cola_mlp_unmerged": ColaConfig(mode="faithful_offload", family="mlp",
                                        hidden=32, taps="qv"),
        "cola_fused_fit_b": ColaConfig(mode="fused_fit", family="lowrank",
                                       rank=8, taps="qv"),
    }
    results = {}
    for name, cc in runs.items():
        sess, losses = train_curve(cfg, cc, steps=steps)
        if cc.mode == "ft":
            trainable = "100%"
        else:
            import jax
            from repro.utils import tree_count
            n = tree_count(sess.adapters)
            trainable = str(n)
        results[name] = losses
        report(fmt_row(name, trainable, f"{losses[0]:.4f}",
                       f"{np.mean(losses[-5:]):.4f}"))

    # the reproduction gates (asserted, not just reported):
    lora = np.mean(results["lora_r8"][-5:])
    cola = np.mean(results["cola_lowrank_unmerged"][-5:])
    colb = np.mean(results["cola_fused_fit_b"][-5:])
    assert abs(lora - cola) / lora < 0.02, "ColA(LowRank) must match LoRA"
    assert abs(lora - colb) / lora < 0.02, "Mode B must match LoRA"
    report("# gate passed: |ColA(LowRank) - LoRA| < 2% (paper: 'the gradient "
           "computed with our methods exactly matches the gradient of LoRA')")
