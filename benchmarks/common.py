"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import ColaConfig
from repro.core.session import ColaSession
from repro.data.pipeline import SyntheticLM
from repro.models import model as M
from repro.optim import optimizers as opt


def bench_cfg(arch="gpt2-small", **kw):
    """The paper's own base-model family (gpt2), reduced for CPU benching."""
    cfg = registry.reduced_config(arch)
    over = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
                d_ff=128, vocab_size=256)
    over.update(kw)
    try:
        return cfg.replace(**over)
    except Exception:
        return cfg


def timed(fn, *args, iters=10, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def train_curve(arch_cfg, cc: ColaConfig, steps=40, batch=8, seq=32, lr=0.05,
                seed=0):
    key = jax.random.PRNGKey(seed)
    params = M.init(arch_cfg, key)
    data = SyntheticLM(arch_cfg, batch=batch, seq=seq, seed=seed)
    sess = ColaSession(arch_cfg, cc, params, key, optimizer=opt.sgd(lr))
    losses = [sess.step(data.batch_at(t)) for t in range(steps)]
    return sess, losses


def fmt_row(*cols):
    return ",".join(str(c) for c in cols)
