"""Paper Tables 10-18 analogue: computation evaluation — server step time,
offloaded fit time, transfer volume (raw vs int8), across batch sizes and
methods, on this host's real device."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_cfg, fmt_row, timed
from repro.configs.base import ColaConfig
from repro.core import gl
from repro.core.offload import Offloader
from repro.models import model as M
from repro.optim import optimizers as opt


def run(report):
    cfg = bench_cfg(n_layers=4, d_model=128, n_heads=8, n_kv_heads=8,
                    d_head=16, d_ff=256)
    key = jax.random.PRNGKey(0)
    params = M.init(cfg, key)
    report("# Tables 10-18 analogue: per-step runtime & transfer bytes")
    report(fmt_row("method", "batch", "server_ms", "offload_fit_ms",
                   "transfer_bytes"))
    for bs in (1, 8, 32):
        batch = {"tokens": jax.random.randint(key, (bs, 64), 0, cfg.vocab_size),
                 "labels": jax.random.randint(key, (bs, 64), 0, cfg.vocab_size)}
        # full FT baseline
        ft = jax.jit(lambda p, b: gl.train_step_ft(cfg, p, b)[0])
        t_ft = timed(ft, params, batch, iters=5)
        report(fmt_row("ft", bs, f"{t_ft*1e3:.2f}", "-", 0))

        for name, mode, compress in (
                ("lora", "lora", "none"),
                ("cola_A", "faithful_offload", "none"),
                ("cola_A_int8", "faithful_offload", "int8"),
                ("cola_B", "fused_fit", "none")):
            cc = ColaConfig(mode=mode if mode != "lora" else "fused_fit",
                            family="lowrank", rank=8, taps="qv",
                            compress=compress)
            adapters = gl.init_adapters(cfg, cc, key)
            spec = gl.make_spec(cfg, cc)
            if mode == "faithful_offload":
                server = jax.jit(
                    lambda p, a, b: gl.server_step_a(cfg, spec, p, a, b)[:2])
                t_srv = timed(server, params, adapters, batch, iters=5)
                off = Offloader(spec, adapters, opt.adamw(1e-3),
                                interval=1, compress=compress)
                _, data = server(params, adapters, batch)
                t0 = time.perf_counter()
                off.push(data)
                off.maybe_fit()
                t_fit = time.perf_counter() - t0
                nbytes = off.stats["pushed_bytes"]
                report(fmt_row(name, bs, f"{t_srv*1e3:.2f}",
                               f"{t_fit*1e3:.2f}", nbytes))
            else:
                server = jax.jit(
                    lambda p, a, b: gl.train_step_b(cfg, spec, p, a, b)[:2])
                t_srv = timed(server, params, adapters, batch, iters=5)
                from repro.utils import tree_size_bytes
                nbytes = tree_size_bytes(adapters)  # grads-sized transfer
                report(fmt_row(name, bs, f"{t_srv*1e3:.2f}", "~0",
                               nbytes))
    report("# cola_A transfer = (x_m, grad_h_m) per tap; int8 ~4x smaller; "
           "cola_B transfer = adapter-gradient-sized (the beyond-paper fix "
           "for the paper's stated transmission limitation)")
