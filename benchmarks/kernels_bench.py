"""Kernel micro-benchmarks: oracle (jnp) wall time on this host + roofline
byte/flop accounting for the TPU target (the kernels themselves require TPU;
interpret mode is correctness-only).

Perf trajectory:
    PYTHONPATH=src:. python benchmarks/kernels_bench.py --baseline
writes ``BENCH_kernels.json`` at the repo root (median/p90 wall per op);
``--check`` diffs a fresh run against the committed baseline and flags
regressions (non-blocking CI job; see benchmarks/perf_baseline.py).
"""
from __future__ import annotations

import os
import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import perf_baseline as pb  # noqa: E402
from benchmarks.common import fmt_row, timed  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402
from repro.kernels import multi_lora as ml  # noqa: E402


def run(report):
    report("# kernel micro-bench: jnp-oracle wall time (CPU) + TPU-side "
           "analytic bytes/flops per call")
    report(fmt_row("kernel", "shape", "cpu_ms", "flops", "hbm_bytes_flash",
                   "hbm_bytes_naive"))
    key = jax.random.PRNGKey(0)

    # flash attention: naive materialises S^2 scores; flash streams kv blocks
    for S in (512, 1024):
        H, K, D = 8, 8, 64
        q = jax.random.normal(key, (1, S, H, D), jnp.bfloat16)
        k = jax.random.normal(key, (1, S, K, D), jnp.bfloat16)
        v = jax.random.normal(key, (1, S, K, D), jnp.bfloat16)
        pos = jnp.arange(S)[None]
        f = jax.jit(lambda q, k, v: ref.sdpa(q, k, v, q_positions=pos,
                                             kv_positions=pos))
        t = timed(f, q, k, v, iters=3)
        flops = 4 * S * S * H * D  # QK^T + PV
        flash_bytes = 2 * (3 * S * H * D + S * H * D)      # q,k,v in + o out
        naive_bytes = flash_bytes + 2 * 4 * H * S * S      # + scores rt f32
        report(fmt_row("flash_attention", f"S={S},H={H},D={D}",
                       f"{t*1e3:.2f}", flops, flash_bytes, naive_bytes))

    # cola_fit: fused vs two-pass (materialising xa in HBM)
    for T in (4096, 16384):
        d, r = 1024, 16
        x = jax.random.normal(key, (T, d), jnp.bfloat16)
        g = jax.random.normal(key, (T, d), jnp.bfloat16)
        A = jax.random.normal(key, (d, r))
        Bm = jax.random.normal(key, (r, d))
        f = jax.jit(lambda x, g: ref.cola_fit_lowrank(x, g, A, Bm))
        t = timed(f, x, g, iters=3)
        flops = 2 * T * d * r * 3
        fused = 2 * (2 * T * d) + 4 * (2 * d * r)
        twopass = fused + 2 * 4 * T * r
        report(fmt_row("cola_fit", f"T={T},d={d},r={r}", f"{t*1e3:.2f}",
                       flops, fused, twopass))

    # multi_lora dense-over-users cost model
    for U in (4, 16):
        T, d, r = 1024, 1024, 16
        x = jax.random.normal(key, (T, d), jnp.bfloat16)
        A = jax.random.normal(key, (U, d, r))
        Bm = jax.random.normal(key, (U, r, d))
        idx = jax.random.randint(key, (T,), 0, U)
        f = jax.jit(lambda x, idx: ref.multi_lora(x, A, Bm, idx))
        t = timed(f, x, idx, iters=3)
        flops = 2 * T * d * r * 2 * U   # TPU kernel: dense over users
        gather_flops = 2 * T * d * r * 2
        report(fmt_row("multi_lora", f"T={T},U={U},r={r}", f"{t*1e3:.2f}",
                       flops, gather_flops, "-"))


# ---------------------------------------------------------------------------
# per-PR perf baseline (BENCH_kernels.json)
# ---------------------------------------------------------------------------

def collect() -> list[dict]:
    """Decode-hot-path op timings on this host (jnp oracles under jit — the
    code the CPU serve path actually runs; Pallas kernels need a TPU)."""
    key = jax.random.PRNGKey(0)
    entries = []

    # single-query decode attention against a slot cache (serving hot path)
    for B, Smax, H, K, D in ((8, 512, 8, 2, 64), (16, 1024, 8, 8, 64)):
        q = jax.random.normal(key, (B, 1, H, D))
        kc = jax.random.normal(jax.random.fold_in(key, 1), (B, Smax, K, D))
        vc = jax.random.normal(jax.random.fold_in(key, 2), (B, Smax, K, D))
        pos = jax.random.randint(jax.random.fold_in(key, 3), (B,), 0, Smax)
        live = jnp.ones((B,), bool)
        f = jax.jit(lambda q, kc, vc, pos, live: ref.sdpa_decode(
            q, kc, vc, pos, live=live))
        entries.append(pb.entry(
            "sdpa_decode", f"B={B},Smax={Smax},H={H},K={K},D={D}",
            **pb.timed_stats(f, q, kc, vc, pos, live)))

    # multi-LoRA decode dispatch: dense-over-users vs grouped (big bank)
    T, d, r = 16, 512, 8
    for U in (16, 256):
        x = jax.random.normal(key, (T, d))
        A = jax.random.normal(jax.random.fold_in(key, 1), (U, d, r))
        Bm = jax.random.normal(jax.random.fold_in(key, 2), (U, r, d))
        idx = jax.random.randint(jax.random.fold_in(key, 3), (T,), 0, U)
        f = jax.jit(lambda x, idx: ref.multi_lora(x, A, Bm, idx))
        entries.append(pb.entry("multi_lora", f"T={T},U={U},d={d},r={r}",
                                **pb.timed_stats(f, x, idx)))

    # int8-stored bank apply (dequant-on-load oracle)
    U = 16
    A = jax.random.normal(jax.random.fold_in(key, 4), (U, d, r))
    Bm = jax.random.normal(jax.random.fold_in(key, 5), (U, r, d))
    A_q, A_s = ml.quant_rows(A)
    B_q, B_s = ml.quant_rows(Bm)
    idx = jax.random.randint(jax.random.fold_in(key, 6), (T,), 0, U)
    x = jax.random.normal(key, (T, d))
    f = jax.jit(lambda x, idx: ref.multi_lora_q8(x, A_q, A_s, B_q, B_s, idx))
    entries.append(pb.entry("multi_lora_q8", f"T={T},U={U},d={d},r={r}",
                            **pb.timed_stats(f, x, idx)))

    # chunked SSD scan (prefill path for ssm archs)
    b, S, H, P, N = 2, 512, 4, 16, 8
    xs = jax.random.normal(key, (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (b, S, H)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.1)
    Bs = jax.random.normal(jax.random.fold_in(key, 3), (b, S, N))
    Cs = jax.random.normal(jax.random.fold_in(key, 4), (b, S, N))
    Dv = jnp.ones((H,))
    f = jax.jit(lambda xs, dt, Bs, Cs: ops.ssd(xs, dt, a, Bs, Cs, Dv,
                                               chunk=128)[0])
    entries.append(pb.entry("ssd_chunked", f"S={S},H={H},P={P},N={N}",
                            **pb.timed_stats(f, xs, dt, Bs, Cs, iters=10)))
    return entries


def telemetry_run(out_dir, report=print):
    """Export CI telemetry artifacts for the kernel suite: one Chrome-trace
    span around the baseline collection with an instant marker per entry, and
    a metric-registry snapshot of every entry's timing/throughput numbers."""
    import json

    from repro.telemetry import Telemetry
    from repro.telemetry.tracing import validate_trace

    os.makedirs(out_dir, exist_ok=True)
    tm = Telemetry(trace=True, out_dir=out_dir)
    tm.name_thread(0, "kernels")
    with tm.span("kernels.collect", cat="bench", tid=0):
        entries = collect()
    for e in entries:
        tm.tracer.instant(f"{e['op']}[{e['shape']}]", cat="bench", tid=0,
                          **e["metrics"])
        tm.registry.absorb(f"bench.{e['op']}.{e['shape']}", e["metrics"])
    doc = tm.tracer.to_doc()
    errors = validate_trace(doc)
    assert not errors, f"exported trace failed validation: {errors}"
    trace_path = os.path.join(out_dir, "kernels_trace.json")
    tm.export_trace(trace_path)
    snap_path = os.path.join(out_dir, "kernels_metrics.json")
    with open(snap_path, "w") as f:
        json.dump(tm.snapshot(), f, indent=1, sort_keys=True)
        f.write("\n")
    report(f"# telemetry artifacts: {trace_path} ({len(entries)} entries), "
           f"{snap_path}")
    return 0


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--telemetry-out" in argv:
        i = argv.index("--telemetry-out")
        return telemetry_run(argv[i + 1])
    return pb.run_cli(argv, collect=collect, baseline_name="BENCH_kernels.json",
                      meta={"suite": "kernels_bench", "device":
                            jax.devices()[0].platform})


if __name__ == "__main__":
    sys.exit(main())
