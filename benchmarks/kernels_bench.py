"""Kernel micro-benchmarks: oracle (jnp) wall time on this host + roofline
byte/flop accounting for the TPU target (the kernels themselves require TPU;
interpret mode is correctness-only)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_row, timed
from repro.kernels import ref


def run(report):
    report("# kernel micro-bench: jnp-oracle wall time (CPU) + TPU-side "
           "analytic bytes/flops per call")
    report(fmt_row("kernel", "shape", "cpu_ms", "flops", "hbm_bytes_flash",
                   "hbm_bytes_naive"))
    key = jax.random.PRNGKey(0)

    # flash attention: naive materialises S^2 scores; flash streams kv blocks
    for S in (512, 1024):
        H, K, D = 8, 8, 64
        q = jax.random.normal(key, (1, S, H, D), jnp.bfloat16)
        k = jax.random.normal(key, (1, S, K, D), jnp.bfloat16)
        v = jax.random.normal(key, (1, S, K, D), jnp.bfloat16)
        pos = jnp.arange(S)[None]
        f = jax.jit(lambda q, k, v: ref.sdpa(q, k, v, q_positions=pos,
                                             kv_positions=pos))
        t = timed(f, q, k, v, iters=3)
        flops = 4 * S * S * H * D  # QK^T + PV
        flash_bytes = 2 * (3 * S * H * D + S * H * D)      # q,k,v in + o out
        naive_bytes = flash_bytes + 2 * 4 * H * S * S      # + scores rt f32
        report(fmt_row("flash_attention", f"S={S},H={H},D={D}",
                       f"{t*1e3:.2f}", flops, flash_bytes, naive_bytes))

    # cola_fit: fused vs two-pass (materialising xa in HBM)
    for T in (4096, 16384):
        d, r = 1024, 16
        x = jax.random.normal(key, (T, d), jnp.bfloat16)
        g = jax.random.normal(key, (T, d), jnp.bfloat16)
        A = jax.random.normal(key, (d, r))
        Bm = jax.random.normal(key, (r, d))
        f = jax.jit(lambda x, g: ref.cola_fit_lowrank(x, g, A, Bm))
        t = timed(f, x, g, iters=3)
        flops = 2 * T * d * r * 3
        fused = 2 * (2 * T * d) + 4 * (2 * d * r)
        twopass = fused + 2 * 4 * T * r
        report(fmt_row("cola_fit", f"T={T},d={d},r={r}", f"{t*1e3:.2f}",
                       flops, fused, twopass))

    # multi_lora dense-over-users cost model
    for U in (4, 16):
        T, d, r = 1024, 1024, 16
        x = jax.random.normal(key, (T, d), jnp.bfloat16)
        A = jax.random.normal(key, (U, d, r))
        Bm = jax.random.normal(key, (U, r, d))
        idx = jax.random.randint(key, (T,), 0, U)
        f = jax.jit(lambda x, idx: ref.multi_lora(x, A, Bm, idx))
        t = timed(f, x, idx, iters=3)
        flops = 2 * T * d * r * 2 * U   # TPU kernel: dense over users
        gather_flops = 2 * T * d * r * 2
        report(fmt_row("multi_lora", f"T={T},U={U},r={r}", f"{t*1e3:.2f}",
                       flops, gather_flops, "-"))
