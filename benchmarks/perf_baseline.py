"""Per-PR performance trajectory: record / compare benchmark baselines.

Each bench suite can save its measurements as a committed JSON baseline
(``BENCH_kernels.json`` / ``BENCH_serve.json`` at the repo root) and later
diff a fresh run against it. Entries are keyed by (op, shape); timings carry
median and p90 wall time, throughputs carry tokens/sec. The comparator flags
entries whose primary metric regressed beyond a relative threshold — wall
times going up, throughputs going down.

CPU wall time on shared CI runners is noisy, so the default threshold is
generous (35%) and the CI job consuming this is non-blocking: the point is a
visible per-PR trajectory, not a flaky gate.

CLI (used by kernels_bench.py / serve_throughput.py):
    --baseline   run and (over)write the committed baseline JSON
    --check      run and diff against the committed baseline; exit 1 on
                 regression (CI marks the job continue-on-error)
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable

import jax
import numpy as np

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_THRESHOLD = 0.35

# metric name -> direction: +1 means larger is better, -1 smaller is better
METRIC_DIRECTION = {
    "median_ms": -1,
    "p90_ms": -1,
    "tokens_per_s": +1,
    "hit_rate": +1,      # adapter-store residency hit rate on a fixed trace
}

# sub-millisecond ops are dominated by timer/dispatch noise on shared CPU
# runners: a relative regression only counts if the absolute delta also
# clears this floor (throughput metrics are macro-scale; no floor needed,
# except hit_rate where a few-percent wobble on a short trace is noise)
MIN_ABS_DELTA = {"median_ms": 0.5, "p90_ms": 0.5, "hit_rate": 0.05}


def timed_stats(fn: Callable, *args, iters: int = 20, warmup: int = 3) -> dict:
    """Median/p90 wall time (ms) of ``fn(*args)`` over ``iters`` runs."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e3)
    return {"median_ms": float(np.median(samples)),
            "p90_ms": float(np.percentile(samples, 90))}


def entry(op: str, shape: str, **metrics: float) -> dict:
    """One baseline row. ``shape`` is a human-readable key ("S=512,H=8,D=64");
    metrics are from METRIC_DIRECTION."""
    unknown = set(metrics) - set(METRIC_DIRECTION)
    assert not unknown, f"unknown metrics {unknown}"
    return {"op": op, "shape": shape,
            "metrics": {k: float(v) for k, v in metrics.items()}}


def save(path: str, entries: list[dict], meta: dict | None = None) -> None:
    doc = {"version": 1, "meta": meta or {}, "entries": entries}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _key(e: dict) -> tuple[str, str]:
    return (e["op"], e["shape"])


def compare(baseline: dict, entries: list[dict],
            threshold: float = DEFAULT_THRESHOLD) -> dict:
    """Diff fresh ``entries`` against a loaded ``baseline`` document.

    Returns {"regressions": [...], "improvements": [...], "missing": [...],
    "new": [...]}; a regression is a primary-direction change beyond
    ``threshold`` relative to the baseline value.
    """
    base = {_key(e): e["metrics"] for e in baseline.get("entries", [])}
    cur = {_key(e): e["metrics"] for e in entries}
    regressions, improvements = [], []
    for k in sorted(set(base) & set(cur)):
        for metric, direction in METRIC_DIRECTION.items():
            if metric not in base[k] or metric not in cur[k]:
                continue
            b, c = base[k][metric], cur[k][metric]
            if b <= 0:
                continue
            rel = (c - b) / b
            rec = {"op": k[0], "shape": k[1], "metric": metric,
                   "baseline": b, "current": c, "rel_change": rel}
            if abs(c - b) < MIN_ABS_DELTA.get(metric, 0.0):
                continue
            if direction * rel < -threshold:
                regressions.append(rec)
            elif direction * rel > threshold:
                improvements.append(rec)
    return {
        "regressions": regressions,
        "improvements": improvements,
        "missing": sorted(set(base) - set(cur)),
        "new": sorted(set(cur) - set(base)),
    }


def report_diff(diff: dict, report: Callable = print) -> None:
    for r in diff["regressions"]:
        report(f"REGRESSION {r['op']}[{r['shape']}] {r['metric']}: "
               f"{r['baseline']:.3f} -> {r['current']:.3f} "
               f"({r['rel_change']:+.0%})")
    for r in diff["improvements"]:
        report(f"improved  {r['op']}[{r['shape']}] {r['metric']}: "
               f"{r['baseline']:.3f} -> {r['current']:.3f} "
               f"({r['rel_change']:+.0%})")
    for k in diff["missing"]:
        report(f"missing   {k[0]}[{k[1]}] (in baseline, not measured)")
    for k in diff["new"]:
        report(f"new       {k[0]}[{k[1]}] (no baseline yet)")
    if not diff["regressions"]:
        report("no regressions vs committed baseline")


def run_cli(argv, *, collect: Callable[[], list[dict]], baseline_name: str,
            meta: dict | None = None, report: Callable = print) -> int:
    """Shared --baseline / --check driver for bench suites. Returns an exit
    code (1 only when --check finds regressions)."""
    import argparse
    p = argparse.ArgumentParser()
    p.add_argument("--baseline", action="store_true",
                   help=f"write {baseline_name} at the repo root")
    p.add_argument("--check", action="store_true",
                   help=f"diff a fresh run against {baseline_name}")
    p.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD)
    args = p.parse_args(argv)
    path = os.path.join(REPO_ROOT, baseline_name)
    entries = collect()
    for e in entries:
        ms = " ".join(f"{k}={v:.3f}" for k, v in e["metrics"].items())
        report(f"{e['op']}[{e['shape']}] {ms}")
    if args.baseline:
        save(path, entries, meta=meta)
        report(f"baseline written: {path}")
        return 0
    if args.check:
        if not os.path.exists(path):
            report(f"no committed baseline at {path}; run --baseline first")
            return 0
        diff = compare(load(path), entries, threshold=args.threshold)
        report_diff(diff, report)
        return 1 if diff["regressions"] else 0
    return 0
