"""Paper Table 1: complexity of computation space of FT / PEFT / ColA.

We measure the actual per-step live bytes on the *server device* for each
method at equal batch sizes — the quantity the paper's table abstracts. On
CPU-JAX we account it analytically from the jaxpr-level state each mode keeps
on-device (params + grads + optimizer states + exported tensors), plus the
compiled temp size of the server step at small scale.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import bench_cfg, fmt_row
from repro.configs.base import ColaConfig
from repro.core import gl
from repro.distributed import steps as dsteps
from repro.models import model as M
from repro.utils import tree_size_bytes


def server_state_bytes(cfg, mode, family="lowrank", users=1):
    """Bytes the server device must hold per mode (paper Table 1 rows)."""
    key = jax.random.PRNGKey(0)
    params = M.init(cfg, key)
    p = tree_size_bytes(params)
    if mode == "ft":
        grads = p
        opt_state = 2 * p + 8       # adam m+v
        adapters = 0
        a_grads = 0
    else:
        cc = ColaConfig(mode="lora", family=family, taps="qv", rank=8)
        ad = gl.init_adapters(cfg, cc, key)
        a = tree_size_bytes(ad) * users
        adapters = a
        if mode == "lora":          # classic PEFT: grads+opt on server
            grads, a_grads, opt_state = 0, a, 2 * a
        elif mode == "cola_unmerged":   # adapters applied on server; grads off
            grads, a_grads, opt_state = 0, 0, 0
        elif mode == "cola_merged":     # adapters folded into base weights
            adapters, grads, a_grads, opt_state = 0, 0, 0, 0
        else:
            raise ValueError(mode)
    return {"params": p, "adapters": adapters, "grads": grads + a_grads,
            "opt_state": opt_state}


def run(report):
    cfg = bench_cfg()
    report("# Table 1 analogue: server-device state bytes per method")
    report(fmt_row("method", "params_B", "adapters_B", "grads_B",
                   "opt_state_B", "total_B"))
    for mode in ("ft", "lora", "cola_unmerged", "cola_merged"):
        for users in (1, 8):
            if mode == "ft" and users > 1:
                continue
            r = server_state_bytes(cfg, mode, users=users)
            total = sum(r.values())
            name = mode if users == 1 else f"{mode}_K{users}"
            report(fmt_row(name, r["params"], r["adapters"], r["grads"],
                           r["opt_state"], total))
    report("# note: cola rows exclude offloaded state (lives on low-cost "
           "device); merged-mode server bytes are independent of K and of "
           "adapter family — the paper's central claim.")
