"""Paper Table 4 analogue: K-user collaboration — 'Joint' vs 'Alone' vs
'Collaboration' on per-user data slices (each user's data comes from a
different synthetic bigram table = different 'task')."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_cfg, fmt_row
from repro.configs.base import ColaConfig
from repro.core.collab import CollabSession
from repro.core.session import ColaSession
from repro.data.pipeline import SyntheticLM
from repro.models import model as M
from repro.optim import optimizers as opt


def run(report):
    cfg = bench_cfg()
    K, steps, B, S = 2, 40, 8, 32
    key = jax.random.PRNGKey(0)
    params = M.init(cfg, key)
    # per-user datasets (different transition tables)
    users_data = [SyntheticLM(cfg, batch=B, seq=S, seed=100 + k)
                  for k in range(K)]

    def mixed_batch(t):
        bs = [users_data[k].batch_at(t) for k in range(K)]
        batch = {key_: np.concatenate([b[key_] for b in bs])[:B]
                 for key_ in bs[0]}
        uid = np.concatenate([np.full(B // K, k) for k in range(K)])
        return ({k_: jnp.asarray(v) for k_, v in batch.items()},
                jnp.asarray(uid))

    def eval_user(p, k):
        b = users_data[k].batch_at(999)
        loss, _ = M.loss_fn(cfg, p, {kk: jnp.asarray(v) for kk, v in b.items()})
        return float(loss)

    report("# Table 4 analogue: joint vs alone vs collaboration (K=2)")
    report(fmt_row("setup", "user0_loss", "user1_loss", "avg"))

    # Joint: one adapter bank on mixed data
    cc = ColaConfig(mode="faithful_offload", family="lowrank", rank=8,
                    taps="qv", merged=True)
    joint = ColaSession(cfg, cc, params, key, optimizer=opt.sgd(0.05))
    for t in range(steps):
        b, _ = mixed_batch(t)
        joint.step(b)
    jp = joint._effective_params()
    l0, l1 = eval_user(jp, 0), eval_user(jp, 1)
    report(fmt_row("joint", f"{l0:.4f}", f"{l1:.4f}", f"{(l0+l1)/2:.4f}"))

    # Alone: separate sessions per user
    alone_losses = []
    for k in range(K):
        sess = ColaSession(cfg, cc, params, jax.random.fold_in(key, k),
                           optimizer=opt.sgd(0.05))
        for t in range(steps):
            b = users_data[k].batch_at(t)
            sess.step({kk: jnp.asarray(v) for kk, v in b.items()})
        alone_losses.append(eval_user(sess._effective_params(), k))
    report(fmt_row("alone", f"{alone_losses[0]:.4f}", f"{alone_losses[1]:.4f}",
                   f"{np.mean(alone_losses):.4f}"))

    # Collaboration: merged banks, per-user gradient isolation
    cc_k = ColaConfig(mode="faithful_offload", family="lowrank", rank=8,
                      taps="qv", merged=True, users=K)
    collab = CollabSession(cfg, cc_k, params, key, optimizer=opt.sgd(0.05))
    for t in range(steps):
        b, uid = mixed_batch(t)
        collab.train_step(b, uid)
    cp = collab.merged_model()
    l0, l1 = eval_user(cp, 0), eval_user(cp, 1)
    report(fmt_row("collaboration", f"{l0:.4f}", f"{l1:.4f}",
                   f"{(l0+l1)/2:.4f}"))
    report("# expectation (paper): collaboration ~ joint ~ alone-per-user; "
           "merging 'alone' banks post-hoc degrades (not shown: alone banks "
           "were never trained merged)")
