"""Paper §C.4 (Figs 4-11): adaptation interval I ablation — with the same
number of server iterations T, the auxiliary models update T/I times on
I-batch buffers (effective batch B*I). Convergence should degrade gracefully
with I; communication (adapter transfers) drops by I."""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_cfg, fmt_row, train_curve
from repro.configs.base import ColaConfig


def run(report):
    cfg = bench_cfg()
    report("# C.4 analogue: adaptation interval ablation (T=64 iterations)")
    report(fmt_row("interval_I", "fits", "adapter_transfers", "loss_final"))
    for interval in (1, 2, 4, 8):
        cc = ColaConfig(mode="faithful_offload", family="lowrank", rank=8,
                        taps="qv", interval=interval)
        sess, losses = train_curve(cfg, cc, steps=64, lr=0.05 * interval)
        report(fmt_row(interval, sess.offloader.stats["fits"],
                       sess.offloader.stats["fits"],
                       f"{np.mean(losses[-5:]):.4f}"))
    report("# larger I: fewer, better-estimated updates (paper: 'satisfactory "
           "convergence with fewer updates to the auxiliary models')")
