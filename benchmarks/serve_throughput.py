"""Serving throughput: batched vs single-row (reference) prefill.

Reports time-to-first-token (TTFT) and decode/prefill tokens/sec across
prompt lengths, slot counts and user counts — the FTaaS serving hot path
(ColA §3.2: one base model, many users' adapters, continuous batching).

    PYTHONPATH=src python benchmarks/serve_throughput.py
or as part of the harness:
    PYTHONPATH=src:. python -m benchmarks.run --only serve_throughput

Perf trajectory: ``--baseline`` writes ``BENCH_serve.json`` at the repo root
(decode/prefill tokens/sec, burst on and off); ``--check`` diffs a fresh run
against the committed baseline (non-blocking CI job; see
benchmarks/perf_baseline.py).

``--chunked-sweep`` runs the chunked-prefill + paged-KV acceptance sweep:
decode tok/s while a long prefill drains (chunked vs whole-prompt) and
allocated cache bytes vs slot count (paged pool vs dense horizon).
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import bench_cfg, fmt_row  # noqa: E402
from repro.configs.base import ColaConfig  # noqa: E402
from repro.core import gl  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.runtime.serve_loop import Request, ServeEngine  # noqa: E402
from repro.telemetry.metrics import percentiles  # noqa: E402


def _reset(eng, cfg, slots, max_len):
    """Reset serving state but keep the engine's compiled jit callables (and,
    with a tiered store, its warmed residency — steady-state, not cold-start)."""
    eng.cache = M.init_cache(cfg, slots, max_len)
    eng.finished = []
    eng.queue = []
    eng.active = [None] * slots
    eng.positions[:] = 0
    for k, v in eng.stats.items():
        eng.stats[k] = 0 if isinstance(v, int) else 0.0
    eng._decode_tick_s.clear()
    eng._prefill_s.clear()
    if eng.store is not None:
        eng.store.reset_counters()


def _run_once(eng, prompts, users, max_new):
    """Submit all requests, run to idle; returns (mean_ttft, wall)."""
    reqs = [Request(rid=i, user=users[i], prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    wall = time.perf_counter() - t0
    ttfts = [r.ttft for r in reqs if r.ttft is not None]
    return float(np.mean(ttfts)), wall


def bench(prompt_len=64, slots=4, n_users=2, n_requests=8, max_new=8, seed=0,
          **engine_kw):
    cfg = bench_cfg("smollm-135m")
    max_len = max(2 * prompt_len, prompt_len + max_new + 8)
    key = jax.random.PRNGKey(seed)
    params = M.init(cfg, key)
    cc = ColaConfig(mode="lora", family="lowrank", taps="qv", rank=4)
    banks = [gl.init_adapters(cfg, cc, jax.random.fold_in(key, u))
             for u in range(n_users)] if n_users else None
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, size=prompt_len)
               for _ in range(n_requests)]
    users = [i % max(n_users, 1) for i in range(n_requests)]

    out = {}
    for mode in ("batched", "reference"):
        eng = ServeEngine(cfg, params, slots=slots, max_len=max_len,
                          user_adapters=banks, prefill_mode=mode, **engine_kw)
        # warmup: compile decode + prefill for the shapes under test
        _run_once(eng, prompts[:slots], users[:slots], max_new)
        _reset(eng, cfg, slots, max_len)
        ttft, wall = _run_once(eng, prompts, users, max_new)
        tp = eng.throughput()
        # throughput() carries the percentile summaries under "ttft"/"latency";
        # keep the run-level mean TTFT as the scalar and expose the tails as
        # ttft_pct so existing consumers of r["ttft"] stay scalar-valued
        out[mode] = {k: v for k, v in tp.items() if k != "ttft"}
        out[mode].update(ttft=ttft, ttft_pct=tp["ttft"], wall=wall)
    return out


def _store_trace(n_users, n_requests, rng):
    """Request trace over a large user population: half the requests follow a
    zipf-ish popularity (a few hot users), half stride through the cold tail —
    so an R-row residency cache sees both reuse (hits) and churn (evictions)."""
    w = 1.0 / np.arange(1, n_users + 1)
    hot = rng.choice(n_users, size=n_requests, p=w / w.sum())
    users = []
    for i in range(n_requests):
        users.append(int(hot[i]) if i % 2 == 0 else (37 * i) % n_users)
    return users


def bench_store(n_users=256, resident=32, slots=8, n_requests=48,
                prompt_len=32, max_new=8, seed=0, check_identity=False,
                **engine_kw):
    """Tiered-store serving over U users with an R-row resident cache.

    Returns hit/eviction/byte metrics and decode throughput; with
    ``check_identity`` the emitted tokens are also asserted bit-identical to
    an all-resident (dense U-user bank) engine on the same trace."""
    cfg = bench_cfg("smollm-135m")
    max_len = max(2 * prompt_len, prompt_len + max_new + 8)
    key = jax.random.PRNGKey(seed)
    params = M.init(cfg, key)
    cc = ColaConfig(mode="lora", family="lowrank", taps="qv", rank=4)
    banks = [gl.init_adapters(cfg, cc, jax.random.fold_in(key, u))
             for u in range(n_users)]
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, size=prompt_len)
               for _ in range(n_requests)]
    users = _store_trace(n_users, n_requests, rng)

    def trace(eng):
        reqs = [Request(rid=i, user=users[i], prompt=p, max_new=max_new)
                for i, p in enumerate(prompts)]
        t0 = time.perf_counter()
        for r in reqs:
            eng.submit(r)
        eng.run_until_idle()
        return [r.out for r in reqs], time.perf_counter() - t0

    eng = ServeEngine(cfg, params, slots=slots, max_len=max_len,
                      user_adapters=banks, resident_slots=resident,
                      **engine_kw)
    _run_once(eng, prompts[:slots], users[:slots], max_new)   # warmup/compile
    _reset(eng, cfg, slots, max_len)
    outs, wall = trace(eng)
    tp = eng.throughput()
    sm = tp["store"]
    out = {"wall": wall, "decode_tok_per_s": tp["decode_tok_per_s"],
           "hit_rate": sm["hit_rate"], "evictions": sm["evictions"],
           "fetch_time": sm["fetch_time"],
           "resident_bytes": sm["resident_bytes"],
           "host_bytes": sm["host_bytes"]}
    if check_identity:
        ref = ServeEngine(cfg, params, slots=slots, max_len=max_len,
                          user_adapters=banks,
                          **{k: v for k, v in engine_kw.items()
                             if k != "cluster_threshold"})
        ref_outs, _ = trace(ref)
        assert outs == ref_outs, (
            f"resident-store serving (R={resident}) diverged from the "
            f"all-resident engine on a U={n_users} trace")
        out["identical_to_all_resident"] = True
        dense_bytes = sum(int(l.nbytes) for l in jax.tree.leaves(ref.bank))
        out["dense_bytes"] = dense_bytes
    return out


def store_sweep(report):
    """U >> R residency sweep: hit rate / evictions / device bytes, plus the
    acceptance trace (U=1024, R=32) checked bit-identical to all-resident."""
    report("# Tiered adapter store: U users through an R-row resident cache")
    report(fmt_row("users", "resident", "store", "hit_rate", "evictions",
                   "resident_MB", "host_MB", "decode_tok_s", "wall_s"))
    for n_users, resident, bank_store in ((256, 16, "f32"), (256, 64, "f32"),
                                          (256, 32, "int8")):
        r = bench_store(n_users=n_users, resident=resident,
                        bank_store=bank_store)
        report(fmt_row(n_users, resident, bank_store, f"{r['hit_rate']:.3f}",
                       r["evictions"], f"{r['resident_bytes'] / 2**20:.2f}",
                       f"{r['host_bytes'] / 2**20:.2f}",
                       f"{r['decode_tok_per_s']:.1f}", f"{r['wall']:.3f}"))
    # acceptance: 1024-user trace, 32 resident rows, bit-identical tokens
    r = bench_store(n_users=1024, resident=32, n_requests=64,
                    check_identity=True)
    report(fmt_row(1024, 32, "f32", f"{r['hit_rate']:.3f}", r["evictions"],
                   f"{r['resident_bytes'] / 2**20:.2f}",
                   f"{r['host_bytes'] / 2**20:.2f}",
                   f"{r['decode_tok_per_s']:.1f}", f"{r['wall']:.3f}"))
    report(f"# U=1024 R=32: bit-identical to all-resident engine; device "
           f"adapter bytes {r['resident_bytes']} vs dense {r['dense_bytes']} "
           f"({r['dense_bytes'] / max(r['resident_bytes'], 1):.0f}x), "
           f"hit rate {r['hit_rate']:.3f}, {r['evictions']} evictions, "
           f"fetch time {r['fetch_time'] * 1e3:.1f}ms")
    assert r["evictions"] > 0, "acceptance trace must exercise eviction"


def bench_interference(chunk=None, prompt_long=1024, slots=4, seed=0,
                       steady_ticks=30):
    """Decode throughput with a long-prompt prefill draining concurrently.

    ``slots - 1`` victim slots decode continuously; after a steady-state
    measurement a ``prompt_long`` request is submitted and decode throughput
    is re-measured until its prefill completes. ``chunk=None`` runs the
    legacy whole-prompt prefill (decode stalls for the full prompt);
    ``chunk=C`` runs chunked prefill over the paged KV layout (one C-token
    chunk per tick, decode interleaved). Returns steady/drain decode tok/s
    and per-tick stall percentiles (p50/p99) for each phase — the stall
    claim is stated on p99, not the mean, because the whole point of
    chunking is bounding the tail."""
    cfg = bench_cfg("smollm-135m")
    max_len = prompt_long + 64
    params = M.init(cfg, jax.random.PRNGKey(seed))
    kw = {}
    if chunk is not None:
        kw = dict(prefill_chunk=chunk, kv_layout="paged", kv_block=16)
    eng = ServeEngine(cfg, params, slots=slots, max_len=max_len, **kw)
    rng = np.random.default_rng(seed)
    victims = [Request(rid=i, user=0,
                       prompt=rng.integers(0, cfg.vocab_size, size=16),
                       max_new=max_len - 24) for i in range(slots - 1)]
    for r in victims:
        eng.submit(r)
    while any(r.t_first is None for r in victims):
        eng.tick()

    def long_req(rid):
        return Request(rid=rid, user=0,
                       prompt=rng.integers(0, cfg.vocab_size,
                                           size=prompt_long), max_new=1)

    # warmup: compile the long-prompt prefill/chunk path off the clock
    warm = long_req(100)
    eng.submit(warm)
    while not warm.done:
        eng.tick()

    def phase(stop):
        d0, t0, gaps = eng.stats["decode_tokens"], time.perf_counter(), []
        while not stop(len(gaps)):
            t1 = time.perf_counter()
            eng.tick()
            gaps.append(time.perf_counter() - t1)
        dt = time.perf_counter() - t0
        return ((eng.stats["decode_tokens"] - d0) / dt, percentiles(gaps),
                len(gaps))

    base, base_pct, _ = phase(lambda n: n >= steady_ticks)
    probe = long_req(101)
    eng.submit(probe)
    drain, drain_pct, drain_ticks = phase(lambda n: probe.t_first is not None)
    return {"base": base, "drain": drain, "ratio": drain / max(base, 1e-9),
            "base_stall": base_pct["p99"], "drain_stall": drain_pct["p99"],
            "base_stall_pct": base_pct, "drain_stall_pct": drain_pct,
            "drain_ticks": drain_ticks}


def _layout_bytes(cfg, slots, max_len, kv_blocks, kv_block=16):
    """Allocated decode-cache bytes per layout, from cache_specs shapes (no
    device allocation — slots=4096 dense would be GBs)."""
    def total(specs):
        return sum(int(np.prod(s.shape)) * np.dtype(s.dtype).itemsize
                   for s in jax.tree.leaves(specs))
    dense = total(M.cache_specs(cfg, slots, max_len))
    ring_len = None
    if M.layer_plan(cfg)[0] == "pairs":
        ring_len = (cfg.local_window or max_len) + kv_block - 1
    paged = total(M.cache_specs(cfg, slots, max_len, kv_layout="paged",
                                kv_blocks=kv_blocks, kv_block=kv_block,
                                ring_len=ring_len))
    paged += slots * (-(-max_len // kv_block)) * 4      # block table
    return dense, paged


def chunked_sweep(report):
    """Chunked prefill + paged KV acceptance sweep (ISSUE 9)."""
    report("# Chunked prefill: decode tok/s while a 1024-token prefill drains")
    report("# (stall columns are per-tick decode-gap percentiles; the claim "
           "is on p99, not the mean)")
    report(fmt_row("mode", "steady_tok_s", "drain_tok_s", "retained",
                   "stall_p50_ms", "stall_p95_ms", "stall_p99_ms",
                   "drain_ticks"))
    rows = {}
    for label, chunk in (("unchunked", None), ("chunk=16", 16),
                         ("chunk=32", 32)):
        r = bench_interference(chunk=chunk)
        rows[label] = r
        p = r["drain_stall_pct"]
        report(fmt_row(label, f"{r['base']:.1f}", f"{r['drain']:.1f}",
                       f"{r['ratio']:.2f}", f"{p['p50'] * 1e3:.1f}",
                       f"{p['p95'] * 1e3:.1f}", f"{p['p99'] * 1e3:.1f}",
                       r["drain_ticks"]))
    un, ch = rows["unchunked"], rows["chunk=16"]
    report(f"# unchunked stalls decode for the whole prompt "
           f"(p99 {un['drain_stall'] * 1e3:.0f}ms, one tick); chunked bounds "
           f"the p99 stall at one chunk round "
           f"({ch['drain_stall'] * 1e3:.0f}ms) "
           f"(target: drain tok/s within 15% of steady on accelerator-class "
           f"decode batches; CPU ticks are dispatch-bound so the retained "
           f"fraction here is dominated by the extra chunk dispatch)")
    assert ch["ratio"] > 2 * un["ratio"], \
        "chunked prefill must retain more decode throughput under drain"
    assert un["drain_stall"] > 3 * ch["drain_stall"], \
        "chunked prefill must bound the p99 decode stall below the " \
        "full-prompt stall"

    report("")
    report("# Paged KV: allocated cache bytes vs slot count (max_len=256, "
           "pool fixed at 1024x16 positions = tokens in flight, not horizon)")
    report(fmt_row("slots", "dense_MB", "paged_MB", "dense/paged"))
    cfg = bench_cfg("smollm-135m")
    sizes = {}
    for slots in (8, 64, 512, 4096):
        dense, paged = _layout_bytes(cfg, slots, max_len=256, kv_blocks=1024)
        sizes[slots] = (dense, paged)
        report(fmt_row(slots, f"{dense / 2**20:.2f}", f"{paged / 2**20:.2f}",
                       f"{dense / paged:.1f}x"))
    # dense scales with slots * max_len; paged only grows by the block table
    assert sizes[4096][0] == 512 * sizes[8][0]
    assert sizes[4096][1] < 2 * sizes[8][1]
    report(f"# 4096 slots: dense {sizes[4096][0] / 2**20:.0f}MB vs paged "
           f"{sizes[4096][1] / 2**20:.1f}MB with a 16k-position pool "
           f"({sizes[4096][0] / sizes[4096][1]:.0f}x)")


def run(report):
    report("# FTaaS serving: batched vs single-row prefill "
           "(TTFT from submit, all requests submitted up front)")
    report(fmt_row("prompt_len", "slots", "users", "mode", "mean_ttft_s",
                   "ttft_p50", "ttft_p95", "ttft_p99", "wall_s",
                   "decode_tok_s", "prefill_tok_s"))
    speedups = {}
    for prompt_len in (16, 64, 128):
        for slots, n_users in ((2, 0), (4, 2), (8, 4)):
            res = bench(prompt_len=prompt_len, slots=slots, n_users=n_users)
            for mode in ("batched", "reference"):
                r = res[mode]
                p = r["ttft_pct"] or {}
                report(fmt_row(prompt_len, slots, n_users, mode,
                               f"{r['ttft']:.4f}",
                               f"{p.get('p50', float('nan')):.4f}",
                               f"{p.get('p95', float('nan')):.4f}",
                               f"{p.get('p99', float('nan')):.4f}",
                               f"{r['wall']:.3f}",
                               f"{r['decode_tok_per_s']:.1f}",
                               f"{r['prefill_tok_per_s']:.1f}"))
            speedups[(prompt_len, slots, n_users)] = (
                res["reference"]["ttft"] / max(res["batched"]["ttft"], 1e-9))
    report("")
    for k, s in speedups.items():
        report(f"# prompt_len={k[0]} slots={k[1]} users={k[2]}: "
               f"batched prefill TTFT speedup {s:.2f}x")
    assert all(s > 1.0 for k, s in speedups.items() if k[0] >= 64), \
        "batched prefill must beat single-row TTFT at prompt length >= 64"
    report("")
    store_sweep(report)


# ---------------------------------------------------------------------------
# telemetry artifact export (--telemetry-out DIR)
# ---------------------------------------------------------------------------

def telemetry_run(out_dir, report=print, prompt_len=48, slots=4, n_users=3,
                  n_requests=8, max_new=8, seed=0):
    """Run a short chunked+paged serve trace with telemetry enabled and export
    the artifacts CI uploads: a Chrome trace-event JSON (load in Perfetto /
    chrome://tracing) and a metric-registry snapshot. The trace is validated
    before writing — a malformed artifact fails the job, not the viewer."""
    from repro.telemetry import Telemetry
    from repro.telemetry.tracing import validate_trace

    os.makedirs(out_dir, exist_ok=True)
    cfg = bench_cfg("smollm-135m")
    max_len = max(2 * prompt_len, prompt_len + max_new + 8)
    key = jax.random.PRNGKey(seed)
    params = M.init(cfg, key)
    cc = ColaConfig(mode="lora", family="lowrank", taps="qv", rank=4)
    banks = [gl.init_adapters(cfg, cc, jax.random.fold_in(key, u))
             for u in range(n_users)]
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, size=prompt_len)
               for _ in range(n_requests)]
    users = [i % n_users for i in range(n_requests)]

    tm = Telemetry(trace=True, out_dir=out_dir)
    eng = ServeEngine(cfg, params, slots=slots, max_len=max_len,
                      user_adapters=banks, prefill_chunk=16,
                      kv_layout="paged", kv_block=16, telemetry=tm)
    _run_once(eng, prompts, users, max_new)

    doc = tm.tracer.to_doc()
    errors = validate_trace(doc)
    assert not errors, f"exported trace failed validation: {errors}"
    trace_path = os.path.join(out_dir, "serve_trace.json")
    tm.export_trace(trace_path)
    snap_path = os.path.join(out_dir, "serve_metrics.json")
    with open(snap_path, "w") as f:
        json.dump(eng.telemetry_snapshot(), f, indent=1, sort_keys=True)
        f.write("\n")
    spans = sum(1 for e in doc["traceEvents"] if e.get("ph") == "X")
    report(f"# telemetry artifacts: {trace_path} ({spans} spans, valid "
           f"trace-event JSON), {snap_path}")
    tp = eng.throughput()
    for k in ("ttft", "decode_tick"):
        p = tp[k]
        if p:
            report(f"# {k}: p50={p['p50'] * 1e3:.1f}ms "
                   f"p95={p['p95'] * 1e3:.1f}ms p99={p['p99'] * 1e3:.1f}ms "
                   f"(n={p['count']})")
    return 0


# ---------------------------------------------------------------------------
# per-PR perf baseline (BENCH_serve.json)
# ---------------------------------------------------------------------------

def _engine_tokens_per_s(max_new=32, **kw):
    """Decode tokens/sec of a warmed engine on a fixed request mix."""
    res = bench(prompt_len=64, slots=4, n_users=2, n_requests=8,
                max_new=max_new, **kw)["batched"]
    return res["decode_tok_per_s"], res["prefill_tok_per_s"]


def collect() -> list[dict]:
    from benchmarks import perf_baseline as pb
    entries = []
    dec1, pre = _engine_tokens_per_s(decode_burst=1)
    entries.append(pb.entry("serve_decode", "slots=4,users=2,burst=1",
                            tokens_per_s=dec1))
    dec8, _ = _engine_tokens_per_s(decode_burst=8)
    entries.append(pb.entry("serve_decode", "slots=4,users=2,burst=8",
                            tokens_per_s=dec8))
    decq8, _ = _engine_tokens_per_s(decode_burst=8, bank_store="int8")
    entries.append(pb.entry("serve_decode", "slots=4,users=2,burst=8,int8",
                            tokens_per_s=decq8))
    entries.append(pb.entry("serve_prefill", "slots=4,users=2,prompt=64",
                            tokens_per_s=pre))
    st = bench_store(n_users=256, resident=32)
    entries.append(pb.entry("serve_store", "users=256,resident=32,slots=8",
                            tokens_per_s=st["decode_tok_per_s"],
                            hit_rate=st["hit_rate"]))
    st8 = bench_store(n_users=256, resident=32, bank_store="int8")
    entries.append(pb.entry("serve_store",
                            "users=256,resident=32,slots=8,int8",
                            tokens_per_s=st8["decode_tok_per_s"],
                            hit_rate=st8["hit_rate"]))
    # chunked prefill + paged KV: steady paged decode and decode-under-drain
    itf = bench_interference(chunk=16)
    entries.append(pb.entry("serve_paged_decode",
                            "slots=4,chunk=16,kv_block=16,steady",
                            tokens_per_s=itf["base"]))
    entries.append(pb.entry("serve_paged_decode",
                            "slots=4,chunk=16,kv_block=16,drain1024",
                            tokens_per_s=itf["drain"]))
    return entries


def main(argv=None) -> int:
    from benchmarks import perf_baseline as pb
    import jax as _jax
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--telemetry-out" in argv:
        i = argv.index("--telemetry-out")
        return telemetry_run(argv[i + 1], lambda *a: print(*a, flush=True))
    if "--store-sweep" in argv:
        store_sweep(lambda *a: print(*a, flush=True))
        return 0
    if "--chunked-sweep" in argv:
        chunked_sweep(lambda *a: print(*a, flush=True))
        return 0
    return pb.run_cli(argv, collect=collect, baseline_name="BENCH_serve.json",
                      meta={"suite": "serve_throughput",
                            "device": _jax.devices()[0].platform})


if __name__ == "__main__":
    if len(sys.argv) > 1:
        sys.exit(main())
    run(lambda *a: print(*a, flush=True))
