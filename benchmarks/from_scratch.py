"""Paper §C.3 (Table 9) analogue: learning from scratch — ColA(Linear, merged)
matches direct full training of the tapped weights; LoRA underfits at low
rank; MLP adapters can overparameterise."""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_cfg, fmt_row, train_curve
from repro.configs.base import ColaConfig


def run(report):
    cfg = bench_cfg(n_layers=2, d_model=48, n_heads=4, n_kv_heads=4,
                    d_head=12, d_ff=96, vocab_size=128)
    report("# C.3 analogue: from-scratch training, final loss")
    report(fmt_row("method", "loss_final"))
    rows = {
        "direct (fused B, linear)": ColaConfig(mode="fused_fit",
                                               family="linear", taps="qv"),
        "cola_linear_merged": ColaConfig(mode="faithful_offload",
                                         family="linear", taps="qv",
                                         merged=True),
        "cola_lowrank_r2": ColaConfig(mode="faithful_offload",
                                      family="lowrank", rank=2, taps="qv",
                                      merged=True),
        "cola_mlp_h64": ColaConfig(mode="faithful_offload", family="mlp",
                                   hidden=64, taps="qv"),
    }
    finals = {}
    for name, cc in rows.items():
        _, losses = train_curve(cfg, cc, steps=80, lr=0.1)
        finals[name] = float(np.mean(losses[-5:]))
        report(fmt_row(name, f"{finals[name]:.4f}"))
    a = finals["direct (fused B, linear)"]
    b = finals["cola_linear_merged"]
    assert abs(a - b) / a < 0.02, "ColA(Linear, merged) == direct training"
    assert finals["cola_lowrank_r2"] >= b - 1e-3, \
        "low-rank approximation must not beat the exact linear update"
    report("# gate passed: ColA(Linear, merged) == direct training (no "
           "approximation), LoRA r=2 underfits — paper C.3")
